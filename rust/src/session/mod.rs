//! Library-first training sessions (DESIGN.md ADR-005).
//!
//! This module is the public face of the training system:
//! [`SessionBuilder`] — typed, chainable, validated configuration —
//! produces an immutable [`TrainSession`] that drives the paper's
//! algorithms over the sharded executor (ADR-004) with a pluggable
//! [`GradientEstimator`](crate::estimator::GradientEstimator) and any
//! number of [`TrainObserver`](crate::observer::TrainObserver) sinks:
//!
//! ```no_run
//! use lgp::prelude::*;
//!
//! # fn main() -> anyhow::Result<()> {
//! let csv = CsvObserver::create(std::path::Path::new("runs/curve.csv"))?;
//! let mut session = SessionBuilder::new()
//!     .preset("tiny")
//!     .algo(Algo::Gpr)
//!     .f(0.25)
//!     .max_steps(20)
//!     .observer(Box::new(csv))
//!     .build()?;
//! session.run()?;
//! println!("val acc {:.3}", session.final_val_acc());
//! # Ok(())
//! # }
//! ```
//!
//! One GPR micro-batch slot and the scatter/reduce update are documented
//! in [`worker`] and DESIGN.md §6; the determinism contract (`--shards N`
//! bit-identical to serial) and the zero-allocation steady state carry
//! over from the `Trainer` this API replaces — the same tests now pin
//! them through `TrainSession`.

pub mod cli;
mod worker;

pub use worker::{ShardWorker, SlotCtx};

use crate::checkpoint::{self, state as ckstate, Dec, Enc};
use crate::config::{Algo, EstimatorKind, OptimKind, RunConfig};
use crate::coordinator::{exec, pool::WorkerPool, reduce};
use crate::data::loader::DataPipeline;
use crate::estimator::{
    ControlVariate, GradientEstimator, MultiTangentForward, NeuralControlVariate, PredictedLgp,
    TrueBackprop,
};
use crate::metrics::{alignment_of, Alignment, AlignmentMeter, Ema, LogRow};
use crate::model::params::{FlatGrad, ParamStore};
use crate::dist::DistSession;
use crate::observer::{CheckpointEvent, DistEvent, DistEventKind, RefitEvent, RunSummary, TrainObserver};
use crate::optim::{OptimConfig, Optimizer};
use crate::predictor::fit::{fit_with_ws, FitBuffer, FitReport};
use crate::predictor::{residuals, Predictor};
use crate::runtime::{DeviceParams, Runtime};
use crate::tensor::{backend, Backend, BackendKind, Workspace};
use crate::util::json::Json;
use crate::util::{shutdown, Stopwatch};
use std::path::PathBuf;

// ---------------------------------------------------------------------------
// SessionBuilder
// ---------------------------------------------------------------------------

/// Typed, chainable configuration for a [`TrainSession`].
///
/// Setters never fail; [`build`](SessionBuilder::build) validates the
/// whole configuration at once (control fraction in (0, 1], `shards >= 1`,
/// `accum >= 1`, a wall-clock budget or a step limit present) *before*
/// touching the artifact directory, then loads the runtime and assembles
/// the immutable session.
///
/// The estimator defaults from [`algo`](SessionBuilder::algo) /
/// [`f`](SessionBuilder::f) / [`adaptive_f`](SessionBuilder::adaptive_f);
/// an explicit [`estimator`](SessionBuilder::estimator) overrides all
/// three.
pub struct SessionBuilder {
    cfg: RunConfig,
    estimator: Option<Box<dyn GradientEstimator>>,
    observers: Vec<Box<dyn TrainObserver>>,
    cancel: Option<shutdown::CancelToken>,
}

impl Default for SessionBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl SessionBuilder {
    /// Builder over [`RunConfig::default`] (tiny preset, GPR, f = 1/4).
    pub fn new() -> SessionBuilder {
        SessionBuilder::from_config(RunConfig::default())
    }

    /// Builder starting from an existing configuration (sweeps, tests).
    pub fn from_config(cfg: RunConfig) -> SessionBuilder {
        SessionBuilder { cfg, estimator: None, observers: Vec::new(), cancel: None }
    }

    /// The configuration as currently accumulated (inspection/tests).
    pub fn config(&self) -> &RunConfig {
        &self.cfg
    }

    /// Artifact directory holding `manifest.json` + the AOT HLO files.
    pub fn artifacts(mut self, dir: impl Into<PathBuf>) -> Self {
        self.cfg.artifacts_dir = dir.into();
        self
    }

    /// Shorthand for `.artifacts(format!("artifacts/{name}"))`.
    pub fn preset(mut self, name: &str) -> Self {
        self.cfg.artifacts_dir = PathBuf::from(format!("artifacts/{name}"));
        self
    }

    /// Algorithm selection; ignored when an explicit estimator is set.
    pub fn algo(mut self, algo: Algo) -> Self {
        self.cfg.algo = algo;
        self
    }

    /// Explicit gradient estimator (overrides `algo`/`f`/`adaptive_f`).
    pub fn estimator(mut self, est: Box<dyn GradientEstimator>) -> Self {
        self.estimator = Some(est);
        self
    }

    /// Pick a zoo member by kind (ADR-006) — the enum form of
    /// [`estimator`](Self::estimator), shared with the `--estimator` CLI
    /// flag. Overrides `algo`; `f`/`seed`/`tangents` still parameterize
    /// the constructed estimator.
    pub fn estimator_kind(mut self, kind: EstimatorKind) -> Self {
        self.cfg.estimator = Some(kind);
        self
    }

    /// Tangent-direction count K for [`MultiTangentForward`].
    pub fn tangents(mut self, k: usize) -> Self {
        self.cfg.tangents = k;
        self
    }

    /// Register an event sink; may be called repeatedly (sinks fire in
    /// registration order).
    pub fn observer(mut self, obs: Box<dyn TrainObserver>) -> Self {
        self.observers.push(obs);
        self
    }

    /// Control fraction f ∈ (0, 1] for the default estimator.
    pub fn f(mut self, f: f64) -> Self {
        self.cfg.f = f;
        self
    }

    /// Gradient-accumulation micro-batches per optimizer update.
    pub fn accum(mut self, accum: usize) -> Self {
        self.cfg.accum = accum;
        self
    }

    pub fn optimizer(mut self, kind: OptimKind) -> Self {
        self.cfg.optimizer = kind;
        self
    }

    pub fn lr(mut self, lr: f64) -> Self {
        self.cfg.lr = lr;
        self
    }

    pub fn weight_decay(mut self, wd: f64) -> Self {
        self.cfg.weight_decay = wd;
        self
    }

    /// Wall-clock budget in seconds; 0 disables the budget (a step limit
    /// must then be set).
    pub fn budget_secs(mut self, secs: f64) -> Self {
        self.cfg.budget_secs = secs;
        self
    }

    /// Maximum optimizer updates; 0 = unlimited (budget governs).
    pub fn max_steps(mut self, steps: usize) -> Self {
        self.cfg.max_steps = steps;
        self
    }

    /// Predictor refit period in optimizer updates.
    pub fn refit_every(mut self, every: usize) -> Self {
        self.cfg.refit_every = every;
        self
    }

    pub fn ridge_lambda(mut self, lambda: f64) -> Self {
        self.cfg.ridge_lambda = lambda;
        self
    }

    pub fn train_size(mut self, n: usize) -> Self {
        self.cfg.train_size = n;
        self
    }

    pub fn val_size(mut self, n: usize) -> Self {
        self.cfg.val_size = n;
        self
    }

    /// Pre-augmentation multiplier (paper: 2x).
    pub fn aug_multiplier(mut self, mult: usize) -> Self {
        self.cfg.aug_multiplier = mult;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    /// Evaluate validation accuracy every N updates (0 = only at end).
    pub fn eval_every(mut self, every: usize) -> Self {
        self.cfg.eval_every = every;
        self
    }

    pub fn out_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.cfg.out_dir = dir.into();
        self
    }

    /// Track ρ̂/κ̂ alignment diagnostics at each refit.
    pub fn track_alignment(mut self, on: bool) -> Self {
        self.cfg.track_alignment = on;
        self
    }

    /// Theorem-4 online control-fraction tuning for the default
    /// estimator.
    pub fn adaptive_f(mut self, on: bool) -> Self {
        self.cfg.adaptive_f = on;
        self
    }

    /// Host tensor backend (`Auto` = one-shot calibration probe).
    pub fn backend(mut self, kind: BackendKind) -> Self {
        self.cfg.backend = kind;
        self
    }

    /// Data-parallel worker shards per optimizer update (ADR-004); any
    /// value is bit-identical to 1.
    pub fn shards(mut self, shards: usize) -> Self {
        self.cfg.shards = shards;
        self
    }

    /// Directory for crash-safe checkpoints (ADR-008); unset = no
    /// checkpointing.
    pub fn checkpoint_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.cfg.checkpoint_dir = Some(dir.into());
        self
    }

    /// Checkpoint every N optimizer updates (0 = only on graceful
    /// shutdown).
    pub fn checkpoint_every(mut self, every: usize) -> Self {
        self.cfg.checkpoint_every = every;
        self
    }

    /// Retain only the newest K valid checkpoint artifacts after each
    /// successful write (0 = keep everything). The artifact just written
    /// is never pruned; torn artifacts never count toward K.
    pub fn checkpoint_keep(mut self, k: usize) -> Self {
        self.cfg.checkpoint_keep = k;
        self
    }

    /// Resume from the newest valid checkpoint before training.
    pub fn resume(mut self, on: bool) -> Self {
        self.cfg.resume = on;
        self
    }

    /// Per-session cancel token (serve control plane, ADR-009). A session
    /// built with a token polls *only* the token at update boundaries —
    /// it neither installs the process SIGINT handler nor clears the
    /// process-global shutdown flag, so hosted sessions cannot clobber
    /// each other or the host's own Ctrl-C handling. Cancellation is
    /// graceful: the final checkpoint still lands (ADR-008).
    pub fn cancel_token(mut self, token: shutdown::CancelToken) -> Self {
        self.cancel = Some(token);
        self
    }

    /// Apply a JSON config document (same keys as the CLI flags).
    ///
    /// Strict: this seam fronts the serve control plane (ADR-009), so
    /// nothing is silently coerced. Unknown keys, wrong value types, and
    /// lossy numerics (`{"shards":-1}`, `{"max_steps":1.5}`) are errors
    /// naming the offending field; enum strings fail immediately with
    /// their option lists; range validation still happens at `build`.
    pub fn apply_json(mut self, j: &Json) -> anyhow::Result<Self> {
        // Every key this document may carry — anything else is a typo or
        // an attack surface, and a typoed key silently falling back to a
        // default is the worst outcome for a remote config submission.
        const KNOWN_KEYS: &[&str] = &[
            "artifacts_dir",
            "algo",
            "optimizer",
            "out_dir",
            "backend",
            "estimator",
            "checkpoint_dir",
            "f",
            "accum",
            "lr",
            "weight_decay",
            "budget_secs",
            "max_steps",
            "refit_every",
            "ridge_lambda",
            "train_size",
            "val_size",
            "aug_multiplier",
            "seed",
            "eval_every",
            "shards",
            "tangents",
            "checkpoint_every",
            "checkpoint_keep",
            "track_alignment",
            "adaptive_f",
            "resume",
        ];
        let obj = j
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("config document must be a JSON object"))?;
        if let Some(k) = obj.keys().find(|k| !KNOWN_KEYS.contains(&k.as_str())) {
            anyhow::bail!("unknown config field '{k}'");
        }
        if let Some(v) = j.get("artifacts_dir") {
            self.cfg.artifacts_dir = PathBuf::from(json_str(v, "artifacts_dir")?);
        }
        if let Some(v) = j.get("algo") {
            self.cfg.algo = json_str(v, "algo")?.parse()?;
        }
        if let Some(v) = j.get("optimizer") {
            self.cfg.optimizer = json_str(v, "optimizer")?.parse()?;
        }
        if let Some(v) = j.get("out_dir") {
            self.cfg.out_dir = PathBuf::from(json_str(v, "out_dir")?);
        }
        if let Some(v) = j.get("backend") {
            self.cfg.backend = json_str(v, "backend")?.parse()?;
        }
        if let Some(v) = j.get("estimator") {
            self.cfg.estimator = Some(json_str(v, "estimator")?.parse()?);
        }
        if let Some(v) = j.get("checkpoint_dir") {
            self.cfg.checkpoint_dir = Some(PathBuf::from(json_str(v, "checkpoint_dir")?));
        }
        if let Some(v) = j.get("f") {
            self.cfg.f = json_f64(v, "f")?;
        }
        if let Some(v) = j.get("accum") {
            self.cfg.accum = json_usize(v, "accum")?;
        }
        if let Some(v) = j.get("lr") {
            self.cfg.lr = json_f64(v, "lr")?;
        }
        if let Some(v) = j.get("weight_decay") {
            self.cfg.weight_decay = json_f64(v, "weight_decay")?;
        }
        if let Some(v) = j.get("budget_secs") {
            self.cfg.budget_secs = json_f64(v, "budget_secs")?;
        }
        if let Some(v) = j.get("max_steps") {
            self.cfg.max_steps = json_usize(v, "max_steps")?;
        }
        if let Some(v) = j.get("refit_every") {
            self.cfg.refit_every = json_usize(v, "refit_every")?;
        }
        if let Some(v) = j.get("ridge_lambda") {
            self.cfg.ridge_lambda = json_f64(v, "ridge_lambda")?;
        }
        if let Some(v) = j.get("train_size") {
            self.cfg.train_size = json_usize(v, "train_size")?;
        }
        if let Some(v) = j.get("val_size") {
            self.cfg.val_size = json_usize(v, "val_size")?;
        }
        if let Some(v) = j.get("aug_multiplier") {
            self.cfg.aug_multiplier = json_usize(v, "aug_multiplier")?;
        }
        if let Some(v) = j.get("seed") {
            self.cfg.seed = json_u64(v, "seed")?;
        }
        if let Some(v) = j.get("eval_every") {
            self.cfg.eval_every = json_usize(v, "eval_every")?;
        }
        if let Some(v) = j.get("shards") {
            self.cfg.shards = json_usize(v, "shards")?;
        }
        if let Some(v) = j.get("tangents") {
            self.cfg.tangents = json_usize(v, "tangents")?;
        }
        if let Some(v) = j.get("checkpoint_every") {
            self.cfg.checkpoint_every = json_usize(v, "checkpoint_every")?;
        }
        if let Some(v) = j.get("checkpoint_keep") {
            self.cfg.checkpoint_keep = json_usize(v, "checkpoint_keep")?;
        }
        if let Some(v) = j.get("track_alignment") {
            self.cfg.track_alignment = json_bool(v, "track_alignment")?;
        }
        if let Some(v) = j.get("adaptive_f") {
            self.cfg.adaptive_f = json_bool(v, "adaptive_f")?;
        }
        if let Some(v) = j.get("resume") {
            self.cfg.resume = json_bool(v, "resume")?;
        }
        Ok(self)
    }

    /// Validate the configuration, load the runtime, and assemble the
    /// session. Validation runs before any filesystem access, so
    /// misconfiguration errors are not masked by missing artifacts.
    pub fn build(self) -> anyhow::Result<TrainSession> {
        let SessionBuilder { cfg, estimator, observers, cancel } = self;
        cfg.validate()?;
        // The Theorem-4 controller is driven by the alignment snapshots
        // the refit produces; without tracking it would silently hold f
        // forever — reject the dead combination instead.
        anyhow::ensure!(
            !(cfg.adaptive_f && !cfg.track_alignment),
            "adaptive_f requires track_alignment (the controller consumes ρ̂/κ̂ snapshots)"
        );
        let mut est = match estimator {
            Some(e) => e,
            None => {
                // Zoo selection (ADR-006): an explicit kind wins, else the
                // legacy algo mapping (baseline → true-backprop,
                // gpr → control-variate).
                let kind = cfg.estimator.unwrap_or(match cfg.algo {
                    Algo::Baseline => EstimatorKind::TrueBackprop,
                    Algo::Gpr => EstimatorKind::ControlVariate,
                });
                anyhow::ensure!(
                    !cfg.adaptive_f || kind == EstimatorKind::ControlVariate,
                    "adaptive_f is only supported by the control-variate estimator \
                     (requested '{}')",
                    kind.as_str()
                );
                match kind {
                    EstimatorKind::TrueBackprop => {
                        Box::new(TrueBackprop) as Box<dyn GradientEstimator>
                    }
                    EstimatorKind::ControlVariate => {
                        Box::new(ControlVariate::new(cfg.f).with_adaptive(cfg.adaptive_f))
                    }
                    EstimatorKind::PredictedLgp => Box::new(PredictedLgp::new(cfg.f)),
                    EstimatorKind::MultiTangent => {
                        Box::new(MultiTangentForward::new(cfg.tangents, cfg.seed))
                    }
                    EstimatorKind::NeuralCv => {
                        Box::new(NeuralControlVariate::new(cfg.f).with_seed(cfg.seed))
                    }
                }
            }
        };
        anyhow::ensure!(
            est.f() > 0.0 && est.f() <= 1.0,
            "estimator '{}': control fraction f must be in (0,1], got {}",
            est.name(),
            est.f()
        );

        // Install the tensor backend first: every dense host path below
        // (fit, Muon, diagnostics) dispatches through it.
        let be = backend::set_active(cfg.backend);
        crate::log_info!("tensor backend: {} (requested: {})", be.name(), cfg.backend.as_str());
        let rt = Runtime::load(&cfg.artifacts_dir)?;
        est.bind(&rt.manifest)?;
        let params = ParamStore::load_init(&rt.manifest)?;
        let opt = Optimizer::new(
            cfg.optimizer,
            OptimConfig {
                lr: cfg.lr as f32,
                weight_decay: cfg.weight_decay as f32,
                backend: be,
                ..OptimConfig::default()
            },
            &params,
            &rt.manifest,
        );
        let pred = Predictor::new(rt.manifest.trunk_params, rt.manifest.width, rt.manifest.rank);
        let fit_buf = FitBuffer::new(rt.manifest.n_fit);
        let data = DataPipeline::build(
            cfg.train_size,
            cfg.val_size,
            rt.manifest.image,
            rt.manifest.classes,
            cfg.aug_multiplier,
            cfg.seed,
        );
        let shards = cfg.shards.max(1);
        if shards > 1 {
            crate::log_info!(
                "sharded executor: {shards} persistent pool workers (ADR-004/ADR-007)"
            );
        }
        let chunks = rt.manifest.n_fit.div_ceil(rt.manifest.n_chunk);
        // Each worker's segment holds exactly its worst-case round-robin
        // share of refit chunks — never more, so the ring cannot slide.
        let seg_cap = chunks.div_ceil(shards) * rt.manifest.n_chunk;
        let workers = (0..shards)
            .map(|_| ShardWorker::new(data.make_view(), seg_cap.max(1)))
            .collect();
        Ok(TrainSession {
            tracker: AlignmentMeter::default(),
            loss_ema: Ema::new(0.2),
            backend: be,
            ws: Workspace::new(),
            // Spawned once here, parked between updates (ADR-007): every
            // scatter below goes through this pool instead of fresh
            // scoped threads.
            pool: WorkerPool::new(shards),
            workers,
            fit_buf,
            est,
            cancel,
            observers,
            cfg,
            rt,
            params,
            opt,
            pred,
            data,
            dev_pred: None,
            dist: None,
            log: Vec::new(),
            cost_units: 0.0,
            examples_seen: 0,
            step: 0,
        })
    }
}

// ---------------------------------------------------------------------------
// Strict JSON field extraction (ADR-009)
// ---------------------------------------------------------------------------
//
// `apply_json` fronts the serve control plane, so every extraction error
// must name the offending field — a bare "expected a number" from a 27-key
// document is undebuggable over the wire.

fn json_str<'a>(v: &'a Json, key: &str) -> anyhow::Result<&'a str> {
    v.as_str().ok_or_else(|| {
        anyhow::anyhow!("config field '{key}': expected a string, got {}", v.to_string())
    })
}

fn json_f64(v: &Json, key: &str) -> anyhow::Result<f64> {
    v.as_f64().ok_or_else(|| {
        anyhow::anyhow!("config field '{key}': expected a number, got {}", v.to_string())
    })
}

fn json_usize(v: &Json, key: &str) -> anyhow::Result<usize> {
    v.as_usize().ok_or_else(|| {
        anyhow::anyhow!(
            "config field '{key}': expected a non-negative integer, got {}",
            v.to_string()
        )
    })
}

fn json_u64(v: &Json, key: &str) -> anyhow::Result<u64> {
    v.as_u64().ok_or_else(|| {
        anyhow::anyhow!(
            "config field '{key}': expected a non-negative integer, got {}",
            v.to_string()
        )
    })
}

fn json_bool(v: &Json, key: &str) -> anyhow::Result<bool> {
    v.as_bool().ok_or_else(|| {
        anyhow::anyhow!("config field '{key}': expected a boolean, got {}", v.to_string())
    })
}

// ---------------------------------------------------------------------------
// TrainSession
// ---------------------------------------------------------------------------

/// An assembled training run: immutable configuration, the runtime and
/// parameter state it drives, the estimator policy, and the observer
/// pipeline. Produced by [`SessionBuilder::build`]; consumed by
/// [`run`](TrainSession::run).
pub struct TrainSession {
    /// The validated configuration (read-only after build).
    pub cfg: RunConfig,
    pub rt: Runtime,
    pub params: ParamStore,
    pub opt: Optimizer,
    pub pred: Predictor,
    fit_buf: FitBuffer,
    pub data: DataPipeline,
    pub tracker: AlignmentMeter,
    /// Smoothed training loss; a session field (not a `run`-local) so a
    /// resumed run reproduces the exact smoothed series (ADR-008).
    loss_ema: Ema,
    /// Host tensor backend selected at build from `cfg.backend` (Auto →
    /// calibration probe); threaded through the fit and the optimizer.
    pub backend: Backend,
    /// Long-lived scratch arena threaded through the predictor refit so
    /// repeat fits reuse the same slabs (ADR-003).
    ws: Workspace,
    /// Persistent parked worker pool (ADR-007): spawned at build, reused
    /// by every update's scatter and by Muon's banded Newton–Schulz
    /// matmuls; replaces the per-update `std::thread::scope` spawn.
    pool: WorkerPool,
    /// One state bundle per configured shard (ADR-004); `workers[0]` is
    /// the serial path's state when `shards = 1`.
    workers: Vec<ShardWorker>,
    dev_pred: Option<crate::runtime::DevicePredictor>,
    /// Connected process group (ADR-010); `None` = single-process. When
    /// set, every update's leaves flow through
    /// [`DistSession::exchange`] instead of the local-only reduce.
    dist: Option<DistSession>,
    /// The gradient-estimation policy (ADR-005).
    est: Box<dyn GradientEstimator>,
    /// Per-session cancel token (serve, ADR-009); `None` = the CLI path,
    /// which polls the process-global SIGINT flag instead.
    cancel: Option<shutdown::CancelToken>,
    observers: Vec<Box<dyn TrainObserver>>,
    pub log: Vec<LogRow>,
    /// Analytic compute units consumed (paper cost model), for the
    /// cost-model bench.
    pub cost_units: f64,
    pub examples_seen: usize,
    step: usize,
}

impl TrainSession {
    /// Pre-compile the artifacts this configuration will touch.
    pub fn warmup(&self) -> anyhow::Result<()> {
        let m = &self.rt.manifest;
        let mut names = vec![m.per_example_grads_name(), "cv_combine".to_string()];
        for f in self.est.warmup_fractions(m) {
            let (mc, mp) = m.split_sizes(f);
            names.push(m.train_grads_name(mc));
            // predict artifacts are only touched when there is a
            // prediction micro-batch (f < 1); host predictors (ADR-006)
            // only need the CheapForward, not the device predict_grad.
            if mp > 0 && self.est.uses_predictor() {
                names.push(m.cheap_fwd_name(mp));
                if !self.est.host_predictor() {
                    names.push(m.predict_grad_name(mc));
                    names.push(m.predict_grad_name(mp));
                }
            }
        }
        names.push(m.cheap_fwd_name(m.val_batch));
        self.rt.warmup(&names)
    }

    pub fn step_count(&self) -> usize {
        self.step
    }

    /// Configured shard count (worker thread pool width).
    pub fn shards(&self) -> usize {
        self.workers.len()
    }

    /// The estimation policy driving this session.
    pub fn estimator(&self) -> &dyn GradientEstimator {
        &*self.est
    }

    /// Control fraction currently in effect (the adaptive controller may
    /// move it between updates).
    pub fn control_fraction(&self) -> f64 {
        self.est.f()
    }

    // ---- elastic multi-process runner (ADR-010) ----------------------------

    /// The handshake geometry this session would demand of a peer: the
    /// ADR-008 fingerprint plus the slot partition (`procs` × local
    /// slots = `accum`) and the data seed. Both sides of
    /// [`crate::dist::connect`] / [`crate::dist::accept_followers`]
    /// derive their geometry this way, so any config divergence
    /// hard-errors at the handshake instead of corrupting a run.
    pub fn dist_geometry(&self, procs: usize) -> crate::dist::Geometry {
        crate::dist::Geometry {
            fingerprint: self.fingerprint(),
            procs,
            accum: self.cfg.accum,
            seed: self.cfg.seed,
        }
    }

    /// Attach a connected process group before [`run`](Self::run). From
    /// here on this process computes only its own contiguous slot group
    /// per update and exchanges leaves with the group; `--procs P` with
    /// `--shards S` is bit-identical to a single-process `--shards P*S`
    /// run (DESIGN.md ADR-010).
    pub fn attach_dist(&mut self, d: DistSession) -> anyhow::Result<()> {
        anyhow::ensure!(self.dist.is_none(), "a dist session is already attached");
        anyhow::ensure!(
            self.step == 0,
            "attach_dist on a session that already ran {} steps",
            self.step
        );
        crate::config::validate_dist(d.procs(), self.cfg.accum)?;
        let ev = DistEvent {
            step: self.step,
            rank: d.rank(),
            procs: d.procs(),
            kind: DistEventKind::Joined,
            detail: if d.is_leader() {
                format!("leader of {} process(es)", d.procs())
            } else {
                "connected to leader".to_string()
            },
        };
        self.dist = Some(d);
        for o in &mut self.observers {
            o.on_dist(&ev)?;
        }
        Ok(())
    }

    /// `(rank, procs)` when a process group is attached.
    pub fn dist_info(&self) -> Option<(usize, usize)> {
        self.dist.as_ref().map(|d| (d.rank(), d.procs()))
    }

    // ---- one optimizer update (scatter/reduce over the shards) -----------

    /// Accumulate `cfg.accum` micro-batch gradients across the shard
    /// workers and return the reduced leaf sums in slot order — gradient
    /// plus the (loss, acc) traces.
    fn execute_update(&mut self, dev: &DeviceParams) -> anyhow::Result<(FlatGrad, f64, f64)> {
        let ready = self.est.predictor_ready(self.pred.fits);
        let plan = self.est.plan(&self.rt.manifest, ready);
        let host_pred = self.est.host_predictor();
        if plan.use_pred && !host_pred {
            // Upload once per update (version-cached) and share read-only
            // across the shards. Host predictors (ADR-006) own their
            // state, so nothing goes to the device.
            let up = self.rt.upload_predictor(&self.pred, self.dev_pred.take())?;
            self.dev_pred = Some(up);
        }
        let ctx = SlotCtx {
            rt: &self.rt,
            dev,
            dev_pred: if plan.use_pred && !host_pred { self.dev_pred.as_ref() } else { None },
            est: &*self.est,
            plan,
            classes: self.rt.manifest.classes,
            head_w: &self.params.head_w,
            width: self.rt.manifest.width,
            smoothing: self.rt.manifest.label_smoothing as f32,
        };
        let per_slot = plan.consumed_per_slot();
        let base = self.data.cursor();
        let accum = self.cfg.accum;
        // In a process group (ADR-010) this rank computes only its own
        // contiguous slot group; slot j here is global slot offset + j,
        // so the stream position is the one a single-process run would
        // use for that slot.
        let (slots, offset) = match &self.dist {
            Some(d) => d.slot_range(accum),
            None => (accum, 0),
        };
        // Scatter through the persistent pool (ADR-007): each parked
        // worker computes its round-robin slots against disjoint stream
        // ranges; gather is slot-ordered, bit-identical to exec::scatter.
        let outs = self.pool.scatter(&mut self.workers, slots, |w, slot| {
            worker::run_micro(&ctx, w, base + (offset + slot) * per_slot)
        })?;

        if self.dist.is_some() {
            // Ship the individual slot leaves; the leader grafts them at
            // their global slot position in the same left-deep fold, so
            // the broadcast mean gradient is bit-identical to a
            // single-process reduce. Nothing (cursor, counters) mutates
            // until the exchange succeeds — a lost peer therefore leaves
            // this session exactly at the last completed update, which is
            // what makes the final checkpoint resumable.
            let leaves: Vec<crate::dist::Leaf> = outs
                .into_iter()
                .map(|o| crate::dist::Leaf {
                    grad: o.grad,
                    loss: o.loss,
                    acc: o.acc,
                    cost: o.cost,
                    examples: o.examples as u64,
                })
                .collect();
            let step = self.step as u64;
            let red = self.dist.as_mut().expect("checked above").exchange(step, leaves)?;
            self.data.advance(accum * per_slot);
            self.cost_units += red.cost_sum;
            self.examples_seen += red.examples as usize;
            return Ok((red.grad, red.loss_sum, red.acc_sum));
        }
        self.data.advance(slots * per_slot);

        // Reduce: fixed topology over slot order (ADR-004) for the
        // gradient and every scalar trace.
        let mut loss_sum = 0.0f64;
        let mut acc_sum = 0.0f64;
        let mut cost_sum = 0.0f64;
        let mut examples = 0usize;
        let mut grads = Vec::with_capacity(outs.len());
        for o in outs {
            loss_sum += o.loss as f64;
            acc_sum += o.acc;
            cost_sum += o.cost;
            examples += o.examples;
            grads.push(o.grad);
        }
        let mut grad = reduce::tree_reduce_grads(grads)
            .expect("accum >= 1 is enforced by RunConfig::validate");
        grad.scale(1.0 / slots as f32);
        self.cost_units += cost_sum;
        self.examples_seen += examples;
        Ok((grad, loss_sum, acc_sum))
    }

    // ---- predictor refit -------------------------------------------------

    /// Collect per-example gradients (chunks scattered across the shards,
    /// gathered in canonical chunk order) and refit (U, B). Also feeds the
    /// Sec. 5.3 alignment tracker with (g_j, ĝ_j) pairs.
    pub fn refit_predictor(&mut self, dev: &DeviceParams) -> anyhow::Result<Option<FitReport>> {
        let (n_chunk, chunks, d, classes, smoothing) = {
            let man = &self.rt.manifest;
            (
                man.n_chunk,
                man.n_fit.div_ceil(man.n_chunk),
                man.width,
                man.classes,
                man.label_smoothing as f32,
            )
        };
        for w in &mut self.workers {
            w.fit_seg.clear();
        }
        let base = self.data.cursor();
        let rt = &self.rt;
        let head_w = &self.params.head_w;
        self.pool.scatter(&mut self.workers, chunks, |w, slot| {
            w.view.batch_at(base + slot * n_chunk, n_chunk, &mut w.x, &mut w.y);
            let (g_rows, a, probs) = rt.per_example_grads(dev, &w.x, &w.y)?;
            let resid = residuals(&probs, &w.y, classes, smoothing);
            let mut h = w.ws.take_tensor(&[n_chunk, d]);
            Predictor::backprop_features_into(&resid, head_w, d, &mut h);
            for (j, g) in g_rows.iter().enumerate() {
                w.fit_seg.push(g, &a[j * d..(j + 1) * d], h.row(j));
            }
            w.ws.give_tensor(h);
            Ok(())
        })?;
        self.data.advance(chunks * n_chunk);
        // fitting also costs compute: fwd+bwd per example
        self.cost_units +=
            chunks as f64 * crate::theory::CostModel::default().cost_vanilla(n_chunk as f64);

        // Gather the worker segments into the fit ring in canonical chunk
        // order — bit-identical to a serial collection by construction.
        let nw = exec::effective_workers(self.workers.len(), chunks);
        self.fit_buf.clear();
        for c in 0..chunks {
            let seg = &self.workers[c % nw].fit_seg;
            let first = (c / nw) * n_chunk;
            for j in first..first + n_chunk {
                self.fit_buf.push(seg.grad(j), &seg.a1(j)[..d], seg.h(j));
            }
        }

        // ADR-006: estimators owning their predictor (neural-cv) fit from
        // the same collected stream; everyone else refits the shared
        // linear predictor.
        let owns_fit = self.est.owns_predictor_fit();
        let report = if owns_fit {
            self.est.fit_own(
                self.backend,
                &self.fit_buf,
                self.cfg.ridge_lambda as f32,
                &mut self.ws,
            )?
        } else {
            fit_with_ws(
                self.backend,
                &mut self.pred,
                &self.fit_buf,
                self.cfg.ridge_lambda as f32,
                &mut self.ws,
            )?
        };
        crate::log_debug!(
            "refit: n={} energy={:.3} rel_err={:.3}",
            report.n,
            report.energy_captured,
            report.rel_error
        );
        // Alignment diagnostics with the *new* predictor on the same
        // samples (plug-in ρ̂/κ̂ of Sec. 5.3) — computed once per refit and
        // cached (a per-step recomputation over n_fit × P_T floats was the
        // top hot-path cost before the perf pass; see EXPERIMENTS.md §Perf).
        // Skipped for estimator-owned fits: `self.pred` was not refitted.
        if self.cfg.track_alignment && !owns_fit {
            let pairs: Vec<(Vec<f32>, Vec<f32>)> = (0..self.fit_buf.len())
                .map(|j| {
                    let a_row = &self.fit_buf.a1(j)[..d];
                    let h_row = self.fit_buf.h(j);
                    let pred_g = self.pred.predict_one_trunk(a_row, h_row);
                    (self.fit_buf.grad(j).to_vec(), pred_g)
                })
                .collect();
            self.tracker.update(alignment_of(&pairs));
        }
        Ok(Some(report))
    }

    // ---- evaluation --------------------------------------------------------

    /// Validation accuracy over all full val batches (CheapForward path).
    pub fn evaluate(&mut self, dev: &DeviceParams) -> anyhow::Result<f64> {
        let man = &self.rt.manifest;
        let mut correct_weighted = 0.0;
        let mut batches = 0usize;
        for (x, y) in self.data.val_batches(man.val_batch) {
            let (_, probs) = self.rt.cheap_fwd(dev, &x, man.val_batch)?;
            correct_weighted += crate::metrics::accuracy(&probs, &y, man.classes);
            batches += 1;
        }
        Ok(if batches == 0 { 0.0 } else { correct_weighted / batches as f64 })
    }

    // ---- crash-safe checkpointing (ADR-008) --------------------------------

    /// Fingerprint over every behavior-affecting config and manifest knob,
    /// stamped into each checkpoint artifact. Deliberately excludes
    /// `shards` (any count is bit-identical, ADR-004) and the output /
    /// budget / checkpoint knobs a resumed run may legitimately change.
    pub fn fingerprint(&self) -> u64 {
        let c = &self.cfg;
        let m = &self.rt.manifest;
        checkpoint::fingerprint_of(&[
            ("algo", format!("{:?}", c.algo)),
            ("estimator", self.est.name().to_string()),
            ("f", format!("{}", c.f)),
            ("adaptive_f", format!("{}", c.adaptive_f)),
            ("tangents", format!("{}", c.tangents)),
            ("accum", format!("{}", c.accum)),
            ("optimizer", format!("{:?}", c.optimizer)),
            ("lr", format!("{}", c.lr)),
            ("weight_decay", format!("{}", c.weight_decay)),
            ("refit_every", format!("{}", c.refit_every)),
            ("ridge_lambda", format!("{}", c.ridge_lambda)),
            ("train_size", format!("{}", c.train_size)),
            ("val_size", format!("{}", c.val_size)),
            ("aug_multiplier", format!("{}", c.aug_multiplier)),
            ("seed", format!("{}", c.seed)),
            ("track_alignment", format!("{}", c.track_alignment)),
            ("backend", self.backend.name().to_string()),
            ("preset", m.preset.clone()),
            ("trunk_params", format!("{}", m.trunk_params)),
            ("width", format!("{}", m.width)),
            ("classes", format!("{}", m.classes)),
            ("n_fit", format!("{}", m.n_fit)),
            ("micro_batch", format!("{}", m.micro_batch)),
        ])
    }

    /// Capture the full mutable session state as a checkpoint container.
    fn build_checkpoint(&self) -> checkpoint::Checkpoint {
        let mut ck = checkpoint::Checkpoint::new(self.fingerprint());
        let mut meta = Enc::new();
        meta.put_u64(self.step as u64);
        meta.put_u64(self.examples_seen as u64);
        meta.put_f64(self.cost_units);
        let (v, alpha, init) = self.loss_ema.parts();
        meta.put_f64(v);
        meta.put_f64(alpha);
        meta.put_bool(init);
        match self.tracker.snapshot() {
            None => meta.put_bool(false),
            Some(a) => {
                meta.put_bool(true);
                meta.put_f64(a.rho);
                meta.put_f64(a.kappa);
                meta.put_f64(a.sigma_g);
                meta.put_f64(a.sigma_h);
                meta.put_u64(a.n as u64);
            }
        }
        ck.add(ckstate::META, meta.into_bytes());
        ck.add(ckstate::PARAMS, ckstate::encode_params(&self.params));
        ck.add(ckstate::OPTIM, ckstate::encode_optimizer(&self.opt));
        ck.add(ckstate::PREDICTOR, ckstate::encode_predictor(&self.pred));
        ck.add(ckstate::FITBUF, ckstate::encode_fitbuf(&self.fit_buf));
        ck.add(ckstate::ESTIMATOR, ckstate::encode_estimator(&*self.est));
        // The data stream is positional (ADR-004): the cursor alone
        // reproduces the exact stream state on a fresh pipeline.
        let mut data = Enc::new();
        data.put_u64(self.cfg.seed);
        data.put_u64(self.data.cursor() as u64);
        ck.add(ckstate::DATA, data.into_bytes());
        ck
    }

    /// Restore every mutable component from a decoded checkpoint. Shape
    /// and identity mismatches (estimator kind, optimizer kind, seeds)
    /// error without partially applying — callers only see a mutated
    /// session on `Ok` because params/optim/pred/fitbuf decoding validates
    /// before overwriting and the scalar fields are assigned last.
    fn restore_from(&mut self, ck: &checkpoint::Checkpoint) -> anyhow::Result<()> {
        let mut meta = Dec::new(ck.section(ckstate::META)?, ckstate::META);
        let step = meta.take_u64()? as usize;
        let examples_seen = meta.take_u64()? as usize;
        let cost_units = meta.take_f64()?;
        let ema_value = meta.take_f64()?;
        let ema_alpha = meta.take_f64()?;
        let ema_init = meta.take_bool()?;
        let mut tracker = AlignmentMeter::default();
        if meta.take_bool()? {
            let a = Alignment {
                rho: meta.take_f64()?,
                kappa: meta.take_f64()?,
                sigma_g: meta.take_f64()?,
                sigma_h: meta.take_f64()?,
                n: meta.take_u64()? as usize,
            };
            tracker.update(Some(a));
        }
        meta.finish()?;

        let mut data = Dec::new(ck.section(ckstate::DATA)?, ckstate::DATA);
        let seed = data.take_u64()?;
        anyhow::ensure!(
            seed == self.cfg.seed,
            "checkpoint data stream seed {seed} differs from session seed {}",
            self.cfg.seed
        );
        let cursor = data.take_u64()? as usize;
        data.finish()?;
        anyhow::ensure!(
            cursor >= self.data.cursor(),
            "checkpoint cursor {cursor} is behind the session's ({})",
            self.data.cursor()
        );

        ckstate::decode_params(&mut self.params, ck.section(ckstate::PARAMS)?)?;
        ckstate::decode_optimizer(&mut self.opt, ck.section(ckstate::OPTIM)?)?;
        ckstate::decode_predictor(&mut self.pred, ck.section(ckstate::PREDICTOR)?)?;
        ckstate::decode_fitbuf(&mut self.fit_buf, ck.section(ckstate::FITBUF)?)?;
        ckstate::decode_estimator(&mut *self.est, ck.section(ckstate::ESTIMATOR)?)?;

        self.data.advance(cursor - self.data.cursor());
        self.step = step;
        self.examples_seen = examples_seen;
        self.cost_units = cost_units;
        self.loss_ema = Ema::from_parts(ema_value, ema_alpha, ema_init);
        self.tracker = tracker;
        // Any device-resident predictor copy predates the restore.
        self.dev_pred = None;
        Ok(())
    }

    /// Encode the session state and write it durably to
    /// `cfg.checkpoint_dir` (tmp + fsync + atomic rename, ADR-008).
    /// No-op returning `Ok(None)` when no checkpoint dir is configured.
    pub fn write_checkpoint(&mut self) -> anyhow::Result<Option<PathBuf>> {
        let Some(dir) = self.cfg.checkpoint_dir.clone() else {
            return Ok(None);
        };
        let sw = Stopwatch::start();
        let bytes = self.build_checkpoint().encode();
        let path = checkpoint::write_atomic(&dir, &checkpoint::file_name(self.step as u64), &bytes)?;
        let ev = CheckpointEvent {
            step: self.step,
            path: path.clone(),
            bytes: bytes.len(),
            write_secs: sw.seconds(),
        };
        for o in &mut self.observers {
            o.on_checkpoint(&ev)?;
        }
        crate::log_info!(
            "checkpoint: step {} -> {} ({} bytes, {:.1} ms)",
            self.step,
            path.display(),
            ev.bytes,
            sw.millis()
        );
        // Retention (--checkpoint-keep): prune only after the new artifact
        // is durably in place, and never the one just written. Housekeeping
        // failure must not abort a training run that just checkpointed
        // successfully — warn and keep going.
        if self.cfg.checkpoint_keep > 0 {
            match checkpoint::prune_keep(&dir, self.cfg.checkpoint_keep, &path) {
                Ok(0) => {}
                Ok(n) => crate::log_info!(
                    "checkpoint: pruned {n} old artifact(s) (keep {})",
                    self.cfg.checkpoint_keep
                ),
                Err(e) => crate::log_warn!("checkpoint: retention prune failed: {e:#}"),
            }
        }
        Ok(Some(path))
    }

    /// Restore from the newest valid checkpoint in `cfg.checkpoint_dir`.
    /// `Ok(None)` (fresh run) when the directory holds no artifacts; a
    /// hard error on fingerprint mismatch or when every artifact is
    /// corrupt beyond the newest-valid fallback.
    pub fn resume_latest(&mut self) -> anyhow::Result<Option<usize>> {
        let dir = self.cfg.checkpoint_dir.clone().ok_or_else(|| {
            anyhow::anyhow!(
                "resume requires a checkpoint directory (--resume needs --checkpoint-dir)"
            )
        })?;
        anyhow::ensure!(
            self.step == 0,
            "resume_latest on a session that already ran {} steps",
            self.step
        );
        match checkpoint::load_latest(&dir, self.fingerprint())? {
            None => {
                crate::log_info!(
                    "resume: no checkpoints in {} — starting fresh",
                    dir.display()
                );
                Ok(None)
            }
            Some(loaded) => {
                self.restore_from(&loaded.ckpt)?;
                crate::log_info!(
                    "resume: restored step {} from {}",
                    self.step,
                    loaded.path.display()
                );
                Ok(Some(self.step))
            }
        }
    }

    // ---- the budgeted training loop ---------------------------------------

    /// Run until the wall-clock budget or step limit, notifying observers
    /// at each step/eval/refit and once at the end. With a checkpoint dir
    /// configured, writes durable artifacts on the periodic schedule and
    /// on SIGINT (graceful shutdown, ADR-008); with `resume` set, first
    /// restores the newest valid checkpoint and continues bit-identically
    /// from the next step.
    ///
    /// With a process group attached ([`attach_dist`](Self::attach_dist),
    /// ADR-010) the leader additionally broadcasts its exit disposition
    /// (complete / interrupted / error) to every follower on the way out,
    /// so followers blocked in an exchange wind down instead of timing
    /// out.
    pub fn run(&mut self) -> anyhow::Result<()> {
        let result = self.run_loop();
        let Some(d) = self.dist.as_mut() else {
            return result.map(|_| ());
        };
        let (code, reason) = match &result {
            Ok(false) => (crate::dist::SHUTDOWN_COMPLETE, "run complete".to_string()),
            Ok(true) => (
                crate::dist::SHUTDOWN_INTERRUPTED,
                "stop requested on the leader".to_string(),
            ),
            Err(e) => (crate::dist::SHUTDOWN_ERROR, format!("{e:#}")),
        };
        // Best-effort on the leader (a follower that already finished at
        // the same max_steps boundary has closed its socket); no-op on
        // followers.
        d.finish(code, &reason);
        let ev = DistEvent {
            step: self.step,
            rank: d.rank(),
            procs: d.procs(),
            kind: DistEventKind::Shutdown,
            detail: format!("code {code}: {reason}"),
        };
        let mut obs_err = None;
        for o in &mut self.observers {
            if let Err(e) = o.on_dist(&ev) {
                obs_err = Some(e);
                break;
            }
        }
        match (result, obs_err) {
            (Err(e), _) => Err(e),
            (Ok(_), Some(e)) => Err(e),
            (Ok(_), None) => Ok(()),
        }
    }

    /// The training loop proper. Returns whether the loop exited on a
    /// stop request (`true`) rather than by exhausting its budget or
    /// step limit (`false`) — the wrapper above turns that into the
    /// coordinated-shutdown code.
    fn run_loop(&mut self) -> anyhow::Result<bool> {
        let mut stopped = false;
        if self.cfg.resume && self.step == 0 {
            self.resume_latest()?;
        }
        // CLI path: (re-)arm the SIGINT handler — `install` re-registers
        // after a previous graceful cycle reset it to SIG_DFL — and clear
        // any stale request. A serve-hosted session (per-session token)
        // must do neither: touching the process-global machinery would
        // clobber concurrent hosted sessions and the server's Ctrl-C.
        if self.cancel.is_none() {
            shutdown::install();
            shutdown::reset();
        }
        self.warmup()?;
        let sw = Stopwatch::start();
        loop {
            if self.cfg.budget_secs > 0.0 && sw.seconds() >= self.cfg.budget_secs {
                break;
            }
            if self.cfg.max_steps > 0 && self.step >= self.cfg.max_steps {
                break;
            }
            let dev = self.rt.upload_params(&self.params)?;
            // Refit schedule: first fit happens after the first update (so
            // early steps aren't all fit overhead), then every refit_every
            // updates — and only when the estimator would actually run a
            // prediction micro-batch once fitted (mp > 0; at f = 1 eq. (1)
            // degenerates to Algorithm 2 and the predictor is never
            // consulted). Asking the plan — not re-deriving the split —
            // keeps custom estimators' split rules authoritative.
            if self.est.uses_predictor()
                && self.est.plan(&self.rt.manifest, true).mp > 0
            {
                let due = if !self.est.predictor_ready(self.pred.fits) {
                    self.step >= 1
                } else {
                    self.cfg.refit_every > 0 && self.step % self.cfg.refit_every == 0
                };
                if due {
                    if let Some(report) = self.refit_predictor(&dev)? {
                        let align = self.tracker.snapshot();
                        // Theorem 4 online: the estimator may retune f.
                        if let Some(new_f) = self.est.observe_alignment(align) {
                            crate::log_info!(
                                "adaptive-f: control fraction -> {new_f:.3}"
                            );
                        }
                        let ev = RefitEvent {
                            step: self.step,
                            report,
                            alignment: align,
                            f: self.est.f(),
                        };
                        for o in &mut self.observers {
                            o.on_refit(&ev)?;
                        }
                    }
                }
            }

            // Scatter micro-batches over the shards, reduce, step. Muon's
            // Newton–Schulz matmuls band across the same pool (ADR-007).
            // In a process group the exchange inside can also deliver the
            // leader's coordinated shutdown (follower side) or a peer
            // loss — both leave this session at the last completed
            // update, because nothing mutates before the exchange
            // succeeds.
            let (grad, loss_sum, acc_sum) = match self.execute_update(&dev) {
                Ok(v) => v,
                Err(e) => {
                    if matches!(
                        e.downcast_ref::<crate::dist::Stopped>(),
                        Some(s) if s.code == crate::dist::SHUTDOWN_COMPLETE
                    ) {
                        // The leader exhausted its budget/step limit at
                        // this boundary; finish here too (final eval and
                        // summary run below, replicated).
                        crate::log_info!(
                            "dist: leader completed the run; stopping at step {}",
                            self.step
                        );
                        break;
                    }
                    if e.downcast_ref::<crate::dist::PeerLost>().is_some() {
                        let ev = self.dist.as_ref().map(|d| DistEvent {
                            step: self.step,
                            rank: d.rank(),
                            procs: d.procs(),
                            kind: DistEventKind::PeerLost,
                            detail: format!("{e:#}"),
                        });
                        if let Some(ev) = ev {
                            for o in &mut self.observers {
                                let _ = o.on_dist(&ev);
                            }
                        }
                        // Persist the last completed update so the run is
                        // resumable from exactly where the group died.
                        match self.write_checkpoint() {
                            Ok(Some(p)) => crate::log_warn!(
                                "dist: peer lost — wrote final checkpoint {}",
                                p.display()
                            ),
                            Ok(None) => {}
                            Err(we) => crate::log_warn!(
                                "dist: final checkpoint after peer loss failed: {we:#}"
                            ),
                        }
                    }
                    return Err(e);
                }
            };
            self.opt.step_pooled(&mut self.params, &grad, &self.rt.manifest, Some(&self.pool));
            self.step += 1;

            let loss = self.loss_ema.push(loss_sum / self.cfg.accum as f64);
            let train_acc = acc_sum / self.cfg.accum as f64;

            // periodic eval + log
            let do_eval = self.cfg.eval_every > 0 && self.step % self.cfg.eval_every == 0;
            let val_acc = if do_eval {
                let dev2 = self.rt.upload_params(&self.params)?;
                self.evaluate(&dev2)?
            } else {
                f64::NAN
            };
            let align = self.tracker.snapshot();
            let row = LogRow {
                step: self.step,
                wall_secs: sw.seconds(),
                loss,
                train_acc,
                val_acc,
                rho: align.map_or(f64::NAN, |a| a.rho),
                kappa: align.map_or(f64::NAN, |a| a.kappa),
                phi: align.map_or(f64::NAN, |a| a.phi(self.est.f())),
                examples_seen: self.examples_seen,
            };
            for o in &mut self.observers {
                o.on_step(&row)?;
            }
            if do_eval {
                for o in &mut self.observers {
                    o.on_eval(row.step, val_acc)?;
                }
                crate::log_info!(
                    "step {:>5} t={:>7.1}s loss={:.4} train_acc={:.3} val_acc={:.3} rho={:.3}",
                    row.step,
                    row.wall_secs,
                    row.loss,
                    row.train_acc,
                    row.val_acc,
                    row.rho
                );
            }
            self.log.push(row);

            // ADR-008: durable checkpoint at the update boundary. The
            // artifact captures post-step-k state, so a resume continues
            // bit-identically at k+1. A graceful-shutdown request always
            // gets a final checkpoint before the loop exits.
            let stop = match &self.cancel {
                Some(token) => token.is_cancelled(),
                None => shutdown::requested(),
            };
            if self.cfg.checkpoint_dir.is_some()
                && ((self.cfg.checkpoint_every > 0
                    && self.step % self.cfg.checkpoint_every == 0)
                    || stop)
            {
                self.write_checkpoint()?;
            }
            if stop {
                crate::log_info!("shutdown requested: stopping after step {}", self.step);
                stopped = true;
                break;
            }
        }
        // Final eval if the last step wasn't an eval step.
        if self.log.last().map_or(true, |r| r.val_acc.is_nan()) {
            let dev = self.rt.upload_params(&self.params)?;
            let val = self.evaluate(&dev)?;
            if let Some(r) = self.log.last_mut() {
                r.val_acc = val;
            }
            let step = self.step;
            for o in &mut self.observers {
                o.on_eval(step, val)?;
            }
        }
        let summary = RunSummary {
            steps: self.step,
            final_val_acc: self.final_val_acc(),
            examples_seen: self.examples_seen,
            cost_units: self.cost_units,
            wall_secs: sw.seconds(),
        };
        for o in &mut self.observers {
            o.on_end(&summary)?;
        }
        Ok(stopped)
    }

    /// Final validation accuracy from the log.
    pub fn final_val_acc(&self) -> f64 {
        self.log
            .iter()
            .rev()
            .find(|r| !r.val_acc.is_nan())
            .map_or(0.0, |r| r.val_acc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::estimator::PredictedLgp;

    #[test]
    fn builder_accumulates_typed_settings() {
        let b = SessionBuilder::new()
            .preset("small")
            .algo(Algo::Baseline)
            .f(0.5)
            .accum(4)
            .optimizer(OptimKind::AdamW)
            .lr(0.003)
            .max_steps(7)
            .seed(9)
            .shards(2)
            .backend(BackendKind::Micro)
            .track_alignment(false);
        let c = b.config();
        assert_eq!(c.artifacts_dir, PathBuf::from("artifacts/small"));
        assert_eq!(c.algo, Algo::Baseline);
        assert_eq!(c.optimizer, OptimKind::AdamW);
        assert_eq!(c.max_steps, 7);
        assert_eq!(c.seed, 9);
        assert_eq!(c.shards, 2);
        assert_eq!(c.backend, BackendKind::Micro);
        assert!(!c.track_alignment);
        assert!((c.f - 0.5).abs() < 1e-12);
        assert!((c.lr - 0.003).abs() < 1e-12);
    }

    #[test]
    fn json_document_maps_onto_builder() {
        let j = Json::parse(
            r#"{"algo":"baseline","f":0.5,"lr":0.1,"optimizer":"adamw",
                "max_steps":7,"track_alignment":false,"backend":"micro","shards":4}"#,
        )
        .unwrap();
        let b = SessionBuilder::new().apply_json(&j).unwrap();
        let c = b.config();
        assert_eq!(c.algo, Algo::Baseline);
        assert_eq!(c.optimizer, OptimKind::AdamW);
        assert_eq!(c.max_steps, 7);
        assert_eq!(c.shards, 4);
        assert!(!c.track_alignment);
        assert!((c.f - 0.5).abs() < 1e-12);
        assert_eq!(c.backend, BackendKind::Micro);
    }

    #[test]
    fn bad_enum_strings_fail_at_apply_time() {
        let j = Json::parse(r#"{"backend":"gpu"}"#).unwrap();
        assert!(SessionBuilder::new().apply_json(&j).is_err());
        let j = Json::parse(r#"{"algo":"nope"}"#).unwrap();
        assert!(SessionBuilder::new().apply_json(&j).is_err());
    }

    #[test]
    fn lossy_numeric_config_is_rejected_with_field_names() {
        // The two ISSUE-9 exemplars: -1 used to saturate to 0, 1.5 used
        // to truncate to 1 — both silently.
        for (doc, field) in [
            (r#"{"shards":-1}"#, "shards"),
            (r#"{"max_steps":1.5}"#, "max_steps"),
            (r#"{"accum":-3}"#, "accum"),
            (r#"{"seed":0.5}"#, "seed"),
            (r#"{"checkpoint_keep":-2}"#, "checkpoint_keep"),
            (r#"{"tangents":"8"}"#, "tangents"),
        ] {
            let j = Json::parse(doc).unwrap();
            let err = SessionBuilder::new().apply_json(&j).unwrap_err();
            let msg = format!("{err}");
            assert!(msg.contains(field), "{doc}: error must name '{field}', got: {msg}");
        }
        // Exact integers (including float-typed ones) still apply.
        let j = Json::parse(r#"{"shards":4,"max_steps":7}"#).unwrap();
        let b = SessionBuilder::new().apply_json(&j).unwrap();
        assert_eq!(b.config().shards, 4);
        assert_eq!(b.config().max_steps, 7);
    }

    #[test]
    fn wrong_typed_and_unknown_config_fields_are_rejected() {
        for (doc, needle) in [
            (r#"{"algo":3}"#, "algo"),
            (r#"{"track_alignment":"yes"}"#, "track_alignment"),
            (r#"{"f":"0.25"}"#, "f"),
            // A typoed key must not silently fall back to the default —
            // "steps" is not a field (the field is "max_steps").
            (r#"{"steps":1.5}"#, "steps"),
            (r#"{"shard":2}"#, "shard"),
        ] {
            let j = Json::parse(doc).unwrap();
            let err = SessionBuilder::new().apply_json(&j).unwrap_err();
            let msg = format!("{err}");
            assert!(msg.contains(needle), "{doc}: error must name '{needle}', got: {msg}");
        }
        // Non-object documents are rejected outright.
        let j = Json::parse("[1,2,3]").unwrap();
        assert!(SessionBuilder::new().apply_json(&j).is_err());
    }

    #[test]
    fn checkpoint_keep_flows_through_json_and_builder() {
        let j = Json::parse(r#"{"checkpoint_keep":3}"#).unwrap();
        let b = SessionBuilder::new().apply_json(&j).unwrap();
        assert_eq!(b.config().checkpoint_keep, 3);
        let b = SessionBuilder::new().checkpoint_keep(5);
        assert_eq!(b.config().checkpoint_keep, 5);
    }

    #[test]
    fn build_validates_before_touching_artifacts() {
        // Invalid settings must surface their own message, not a missing-
        // artifacts error, even though the artifacts_dir does not exist.
        let err = SessionBuilder::new().f(1.5).build().unwrap_err();
        assert!(format!("{err}").contains("f must be in (0,1]"), "{err}");
        let err = SessionBuilder::new().shards(0).build().unwrap_err();
        assert!(format!("{err}").contains("shards must be >= 1"), "{err}");
        let err = SessionBuilder::new().max_steps(0).budget_secs(0.0).build().unwrap_err();
        assert!(format!("{err}").contains("budget or a step limit"), "{err}");
        let err = SessionBuilder::new().accum(0).build().unwrap_err();
        assert!(format!("{err}").contains("accum"), "{err}");
    }

    #[test]
    fn adaptive_f_without_alignment_tracking_is_rejected() {
        // The controller consumes ρ̂/κ̂ snapshots; without tracking it
        // would silently never adapt — a dead configuration.
        let err = SessionBuilder::new()
            .adaptive_f(true)
            .track_alignment(false)
            .build()
            .unwrap_err();
        assert!(format!("{err}").contains("track_alignment"), "{err}");
    }

    #[test]
    fn explicit_estimator_is_validated_too() {
        let err = SessionBuilder::new()
            .estimator(Box::new(PredictedLgp::new(0.0)))
            .build()
            .unwrap_err();
        assert!(format!("{err}").contains("control fraction"), "{err}");
    }

    #[test]
    fn estimator_kind_and_tangents_accumulate() {
        let b = SessionBuilder::new()
            .estimator_kind(EstimatorKind::MultiTangent)
            .tangents(16);
        assert_eq!(b.config().estimator, Some(EstimatorKind::MultiTangent));
        assert_eq!(b.config().tangents, 16);
        // And through JSON, with an alias.
        let j = Json::parse(r#"{"estimator":"ncv","tangents":4}"#).unwrap();
        let b = SessionBuilder::new().apply_json(&j).unwrap();
        assert_eq!(b.config().estimator, Some(EstimatorKind::NeuralCv));
        assert_eq!(b.config().tangents, 4);
        let j = Json::parse(r#"{"estimator":"nope"}"#).unwrap();
        assert!(SessionBuilder::new().apply_json(&j).is_err());
    }

    #[test]
    fn adaptive_f_rejects_non_control_variate_kinds() {
        let err = SessionBuilder::new()
            .estimator_kind(EstimatorKind::PredictedLgp)
            .adaptive_f(true)
            .build()
            .unwrap_err();
        assert!(format!("{err}").contains("control-variate"), "{err}");
        // Tangent count is validated like every other range check.
        let err = SessionBuilder::new()
            .estimator_kind(EstimatorKind::MultiTangent)
            .tangents(0)
            .build()
            .unwrap_err();
        assert!(format!("{err}").contains("tangent"), "{err}");
    }
}
