//! Per-shard worker state and the micro-batch slot task (ADR-004 +
//! ADR-005).
//!
//! One slot of one optimizer update runs entirely on the calling worker
//! thread: gather the control batch, true Forward+Backward, then — when
//! the estimator's plan says so — the predictor passes and the
//! estimator's combine. The estimator is shared read-only across the
//! scatter (`&dyn GradientEstimator`); all mutable state lives in the
//! worker.

use crate::data::loader::ShardDataView;
use crate::estimator::{CombineCx, GradientEstimator, PredictInput, UpdatePlan};
use crate::metrics::accuracy;
use crate::model::params::FlatGrad;
use crate::predictor::fit::FitBuffer;
use crate::runtime::{DeviceParams, DevicePredictor, Runtime, TrainOut};
use crate::tensor::Workspace;
use crate::theory::CostModel;

/// Everything one worker thread owns (ADR-004). Nothing here is shared:
/// the scatter hands each worker's `&mut ShardWorker` to exactly one
/// scoped thread, which is what makes the update data-race-free without
/// locks on the hot path.
pub struct ShardWorker {
    /// Position-addressed window onto the training stream (shared
    /// `Arc<Dataset>`, private per-epoch permutation cache).
    pub(crate) view: ShardDataView,
    /// This worker's refit ring segment: its round-robin share of the
    /// per-example gradient chunks lands here, then the session gathers
    /// segments in canonical chunk order.
    pub(crate) fit_seg: FitBuffer,
    /// Private scratch arena — per-worker reuse keeps the steady state
    /// allocation-free with no cross-thread churn (the `alloc-counter`
    /// test asserts this per thread).
    pub(crate) ws: Workspace,
    /// Gather scratch for the control batch (capacity retained).
    pub(crate) x: Vec<f32>,
    pub(crate) y: Vec<i32>,
    /// Gather scratch for the prediction batch.
    pub(crate) xp: Vec<f32>,
    pub(crate) yp: Vec<i32>,
}

impl ShardWorker {
    pub(crate) fn new(view: ShardDataView, fit_seg_capacity: usize) -> ShardWorker {
        ShardWorker {
            view,
            fit_seg: FitBuffer::new(fit_seg_capacity),
            ws: Workspace::new(),
            x: Vec::new(),
            y: Vec::new(),
            xp: Vec::new(),
            yp: Vec::new(),
        }
    }
}

/// Per-update constants a micro-batch slot task needs — snapshotted by
/// the session before the scatter so worker threads share only immutable
/// state.
pub struct SlotCtx<'a> {
    pub rt: &'a Runtime,
    pub dev: &'a DeviceParams,
    pub dev_pred: Option<&'a DevicePredictor>,
    /// The estimation policy: split plan + combine (ADR-005).
    pub est: &'a dyn GradientEstimator,
    pub plan: UpdatePlan,
    pub classes: usize,
    /// Host copy of the head weights (width, classes row-major) — host
    /// predictors (ADR-006) backprop residuals through it on-thread.
    pub head_w: &'a [f32],
    pub width: usize,
    pub smoothing: f32,
}

/// One micro-batch slot's contribution: the gradient leaf plus the scalar
/// traces, reduced by the session in slot order.
pub(crate) struct MicroOut {
    pub grad: FlatGrad,
    pub loss: f32,
    pub acc: f64,
    pub cost: f64,
    pub examples: usize,
}

/// One micro-batch slot (any estimator) at stream position `pos`, running
/// entirely on the calling worker thread (DESIGN.md §6):
///
///   control:    train_grads  -> g_ct, a_c, p_c     (Forward + Backward)
///               predict_grad -> g_cp               (predictor on control)
///   prediction: cheap_fwd    -> a_p, p_p           (CheapForward)
///               predict_grad -> g_p
///   combine:    estimator-owned (eq. 1 for ControlVariate)
///
/// With `mp = 0` (TrueBackprop, or ControlVariate at f = 1) only the
/// control pass runs — Algorithm 2 is the degenerate plan, not a second
/// code path.
pub(crate) fn run_micro(
    ctx: &SlotCtx,
    w: &mut ShardWorker,
    pos: usize,
) -> anyhow::Result<MicroOut> {
    let cost = CostModel::default();
    let plan = ctx.plan;

    // -- control micro-batch: true gradient + activations ----------------
    w.view.batch_at(pos, plan.mc, &mut w.x, &mut w.y);
    let ctrl = ctx.rt.train_grads(ctx.dev, &w.x, &w.y, plan.mc)?;
    let acc = accuracy(&ctrl.probs, &w.y, ctx.classes);
    let c_units = cost.cost_vanilla(plan.mc as f64) + cost.cheap_forward * plan.mp as f64;
    let examples = plan.mc + plan.mp;

    // Until the first fit the predictor is identically zero; eq. (1) then
    // reduces to g_ct (still unbiased). Skip the device calls — and the
    // prediction draw (consumed_per_slot matches).
    if !plan.use_pred {
        let TrainOut { loss, g_trunk, g_head_w, g_head_b, .. } = ctrl;
        let mut grad = FlatGrad { trunk: g_trunk, head_w: g_head_w, head_b: g_head_b };
        // Control-only post-transform (ADR-006): seeded by the stream
        // position — a pure function of the data cursor — so the result
        // is bit-identical at every shard count. Identity for all but
        // MultiTangentForward.
        ctx.est.transform_control(&mut grad, pos as u64);
        return Ok(MicroOut { grad, loss, acc, cost: c_units, examples });
    }

    // -- prediction micro-batch inputs: CheapForward ----------------------
    w.view.batch_at(pos + plan.mc, plan.mp, &mut w.xp, &mut w.yp);
    let (a_p, probs_p) = ctx.rt.cheap_fwd(ctx.dev, &w.xp, plan.mp)?;

    let (g_cp, g_p) = if ctx.est.host_predictor() {
        // Host predictor (ADR-006): the estimator owns the prediction —
        // no device predictor upload, no predict_grad round-trips.
        let zeros = || FlatGrad {
            trunk: vec![0.0; ctrl.g_trunk.len()],
            head_w: vec![0.0; ctrl.g_head_w.len()],
            head_b: vec![0.0; ctrl.g_head_b.len()],
        };
        let mut g_cp = zeros();
        let mut g_p = zeros();
        ctx.est.host_predict(
            &PredictInput {
                a: &ctrl.a,
                probs: &ctrl.probs,
                y: &w.y,
                head_w: ctx.head_w,
                m: plan.mc,
                width: ctx.width,
                classes: ctx.classes,
                smoothing: ctx.smoothing,
            },
            &mut g_cp,
        )?;
        ctx.est.host_predict(
            &PredictInput {
                a: &a_p,
                probs: &probs_p,
                y: &w.yp,
                head_w: ctx.head_w,
                m: plan.mp,
                width: ctx.width,
                classes: ctx.classes,
                smoothing: ctx.smoothing,
            },
            &mut g_p,
        )?;
        (g_cp, g_p)
    } else {
        let dev_pred = ctx
            .dev_pred
            .expect("session uploads the predictor before a use_pred scatter");

        // -- predictor on the control micro-batch (g_cp) ------------------
        let pc = ctx
            .rt
            .predict_grad(&ctrl.a, &ctrl.probs, &w.y, ctx.dev, dev_pred, plan.mc)?;

        // -- predictor on the prediction micro-batch (g_p) ----------------
        let pp = ctx
            .rt
            .predict_grad(&a_p, &probs_p, &w.yp, ctx.dev, dev_pred, plan.mp)?;

        (
            FlatGrad { trunk: pc.g_trunk, head_w: pc.g_head_w, head_b: pc.g_head_b },
            FlatGrad { trunk: pp.g_trunk, head_w: pp.g_head_w, head_b: pp.g_head_b },
        )
    };

    // -- estimator-owned combine (ADR-005) --------------------------------
    let mut g = FlatGrad { trunk: ctrl.g_trunk, head_w: ctrl.g_head_w, head_b: ctrl.g_head_b };
    ctx.est.combine(&CombineCx { rt: Some(ctx.rt) }, &mut g, &g_cp, &g_p, plan.f_eff)?;
    Ok(MicroOut { grad: g, loss: ctrl.loss, acc, cost: c_units, examples })
}
