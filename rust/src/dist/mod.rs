//! Elastic multi-process distributed runner (DESIGN.md ADR-010).
//!
//! One training run spans `P` processes over loopback/LAN TCP: rank 0
//! (the *leader*) owns the observers, checkpoints, and stop decisions;
//! ranks 1..P (*followers*) each drive their own ADR-007 worker pool
//! over a contiguous group of micro-batch slots. Every update:
//!
//! 1. each process computes its slot group's gradient leaves locally
//!    (slot `j` of rank `r` reads stream position
//!    `cursor + (r·accum/P + j)·per_slot` — the ADR-004 positional
//!    contract, so the data partition is a pure function of geometry);
//! 2. followers ship their *individual slot leaves* to the leader
//!    ([`wire::Msg::Leaves`]);
//! 3. the leader folds all `accum` leaves with the same left-deep
//!    slot-ordered fold as `coordinator::reduce::tree_reduce_grads` —
//!    remote leaves are grafted at the exact tree position a
//!    single-process run would give them, which is why the result is
//!    bit-identical to `--shards P*S` single-process (f32 addition is
//!    not associative, so folding per-process *partial sums* would NOT
//!    be);
//! 4. the leader broadcasts the scaled mean gradient and folded scalar
//!    traces ([`wire::Msg::Reduced`]); every process applies the same
//!    optimizer step, so params/optimizer/EMA state evolve identically
//!    everywhere (refit and eval are replicated locally — the fit
//!    gather is canonical chunk-ordered and therefore worker-count
//!    independent, so they need no communication at all).
//!
//! Failure model: state mutation happens only *after* a successful
//! exchange, so a peer death ([`PeerLost`]) leaves the session at the
//! last completed update — the leader writes a valid, resumable ADR-008
//! checkpoint and exits nonzero. Graceful stops flow leader → follower
//! as [`wire::Msg::Shutdown`] ([`Stopped`] on the follower side).

use crate::model::params::FlatGrad;
use anyhow::{bail, ensure, Context as _, Result};
use std::io::{BufReader, BufWriter};
use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

pub mod wire;

pub use wire::{Hello, Leaf, Msg, Reduced, PROTO_VERSION};
pub use wire::{SHUTDOWN_COMPLETE, SHUTDOWN_ERROR, SHUTDOWN_INTERRUPTED};

/// Handshake / connect patience. Spawning P release binaries and loading
/// artifacts can take a while on a cold cache.
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(60);

/// Per-message read patience during the update loop. One exchange waits
/// at most one peer's local compute (slots + refit + eval); a peer that
/// goes silent longer than this is treated as lost.
const EXCHANGE_TIMEOUT: Duration = Duration::from_secs(300);

// ---------------------------------------------------------------------------
// Typed errors the session loop dispatches on
// ---------------------------------------------------------------------------

/// A peer died or desynchronized mid-run. The leader reacts by writing a
/// final checkpoint at the last completed update and aborting nonzero.
#[derive(Debug)]
pub struct PeerLost {
    pub rank: usize,
    pub detail: String,
}

impl std::fmt::Display for PeerLost {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "dist: lost peer rank {} ({})", self.rank, self.detail)
    }
}

impl std::error::Error for PeerLost {}

/// The leader told this follower to stop ([`wire::Msg::Shutdown`]).
/// `SHUTDOWN_COMPLETE` is a clean coordinated finish; anything else is
/// an abnormal exit the follower propagates as an error.
#[derive(Debug)]
pub struct Stopped {
    pub code: u8,
    pub reason: String,
}

impl std::fmt::Display for Stopped {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "dist: leader shutdown (code {}: {})", self.code, self.reason)
    }
}

impl std::error::Error for Stopped {}

// ---------------------------------------------------------------------------
// Connection
// ---------------------------------------------------------------------------

/// One framed, message-oriented peer connection (buffered both ways;
/// the protocol is strictly request/response so one stream suffices).
struct Conn {
    r: BufReader<TcpStream>,
    w: BufWriter<TcpStream>,
    /// Remote rank, for diagnostics.
    rank: usize,
}

impl Conn {
    fn new(stream: TcpStream, rank: usize, timeout: Duration) -> Result<Conn> {
        stream.set_nodelay(true).context("dist: set_nodelay")?;
        stream.set_read_timeout(Some(timeout)).context("dist: set_read_timeout")?;
        stream.set_write_timeout(Some(timeout)).context("dist: set_write_timeout")?;
        let r = BufReader::new(stream.try_clone().context("dist: cloning stream")?);
        Ok(Conn { r, w: BufWriter::new(stream), rank })
    }

    fn send(&mut self, msg: &Msg) -> Result<()> {
        wire::send_frame(&mut self.w, &msg.encode())
            .with_context(|| format!("dist: sending {} to rank {}", msg.kind(), self.rank))
    }

    fn recv(&mut self) -> Result<Msg> {
        let payload = wire::recv_frame(&mut self.r)
            .with_context(|| format!("dist: receiving from rank {}", self.rank))?;
        Msg::decode(&payload)
    }

    fn set_timeout(&mut self, timeout: Duration) -> Result<()> {
        self.w.get_ref().set_read_timeout(Some(timeout))?;
        self.w.get_ref().set_write_timeout(Some(timeout))?;
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Geometry + handshake
// ---------------------------------------------------------------------------

/// Everything two processes must agree on before exchanging gradients.
/// Mismatches hard-error during the handshake, mirroring the ADR-008
/// fingerprint check on resume.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Geometry {
    /// ADR-008 config/manifest fingerprint (`TrainSession::fingerprint`).
    pub fingerprint: u64,
    pub procs: usize,
    /// Global `--accum`; must satisfy `accum % procs == 0`.
    pub accum: usize,
    pub seed: u64,
}

impl Geometry {
    /// Validate the slot partition tiles the update evenly.
    pub fn validate(&self) -> Result<()> {
        crate::config::validate_dist(self.procs, self.accum)
    }

    fn check_hello(&self, h: &Hello) -> Result<()> {
        ensure!(
            h.proto == PROTO_VERSION,
            "peer speaks dist protocol v{} (this build speaks v{PROTO_VERSION})",
            h.proto
        );
        ensure!(
            h.fingerprint == self.fingerprint,
            "peer fingerprint {:016x} differs from ours {:016x} — different experiment",
            h.fingerprint,
            self.fingerprint
        );
        ensure!(
            h.procs as usize == self.procs && h.accum as usize == self.accum,
            "peer geometry procs={} accum={} differs from ours procs={} accum={}",
            h.procs,
            h.accum,
            self.procs,
            self.accum
        );
        ensure!(
            h.seed == self.seed,
            "peer data seed {} differs from ours {}",
            h.seed,
            self.seed
        );
        Ok(())
    }
}

/// Leader side of the handshake: accept `procs - 1` followers on
/// `listener`, validate each [`Hello`] against `geom`, reply `Welcome`
/// (or `Reject` + hard error). `poll` runs while waiting (the launcher
/// uses it to notice a follower that died before connecting); return an
/// error from it to abort the accept loop.
pub fn accept_followers(
    listener: &TcpListener,
    geom: &Geometry,
    mut poll: impl FnMut() -> Result<()>,
) -> Result<DistSession> {
    geom.validate()?;
    ensure!(geom.procs >= 2, "dist accept needs procs >= 2 (got {})", geom.procs);
    listener.set_nonblocking(true).context("dist: listener nonblocking")?;
    let deadline = Instant::now() + HANDSHAKE_TIMEOUT;
    let mut peers: Vec<Conn> = Vec::with_capacity(geom.procs - 1);
    while peers.len() < geom.procs - 1 {
        poll()?;
        ensure!(
            Instant::now() < deadline,
            "dist: timed out waiting for followers ({}/{} connected)",
            peers.len(),
            geom.procs - 1
        );
        let stream = match listener.accept() {
            Ok((s, _)) => s,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(20));
                continue;
            }
            Err(e) => return Err(e).context("dist: accepting follower"),
        };
        stream.set_nonblocking(false).context("dist: stream blocking")?;
        let mut conn = Conn::new(stream, 0, HANDSHAKE_TIMEOUT)?;
        let msg = conn.recv()?;
        let hello = match msg {
            Msg::Hello(h) => h,
            m => bail!("dist handshake: expected Hello, got {}", m.kind()),
        };
        let rank = hello.rank as usize;
        let rank_ok = (1..geom.procs).contains(&rank) && !peers.iter().any(|p| p.rank == rank);
        let verdict = geom.check_hello(&hello).and_then(|()| {
            ensure!(rank_ok, "rank {rank} invalid or already connected (procs {})", geom.procs);
            Ok(())
        });
        if let Err(e) = verdict {
            let _ = conn.send(&Msg::Reject { reason: format!("{e:#}") });
            return Err(e.context("dist handshake rejected a follower"));
        }
        conn.rank = rank;
        conn.send(&Msg::Welcome { proto: PROTO_VERSION })?;
        crate::log_info!("dist: follower rank {rank} joined ({} of {})", peers.len() + 1, geom.procs - 1);
        peers.push(conn);
    }
    peers.sort_by_key(|p| p.rank);
    for p in &mut peers {
        p.set_timeout(EXCHANGE_TIMEOUT)?;
    }
    Ok(DistSession { rank: 0, procs: geom.procs, role: Role::Leader { peers } })
}

/// Follower side of the handshake: connect to the leader (with retry —
/// the leader may still be loading artifacts), send [`Hello`], and wait
/// for the verdict.
pub fn connect(addr: &str, rank: usize, geom: &Geometry) -> Result<DistSession> {
    geom.validate()?;
    ensure!(
        (1..geom.procs).contains(&rank),
        "dist connect: rank {rank} out of range for procs {}",
        geom.procs
    );
    let deadline = Instant::now() + HANDSHAKE_TIMEOUT;
    let stream = loop {
        match TcpStream::connect(addr) {
            Ok(s) => break s,
            Err(e) => {
                ensure!(
                    Instant::now() < deadline,
                    "dist: could not reach leader at {addr}: {e}"
                );
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    };
    let mut conn = Conn::new(stream, 0, HANDSHAKE_TIMEOUT)?;
    conn.send(&Msg::Hello(Hello {
        proto: PROTO_VERSION,
        fingerprint: geom.fingerprint,
        rank: rank as u32,
        procs: geom.procs as u32,
        accum: geom.accum as u32,
        seed: geom.seed,
    }))?;
    match conn.recv()? {
        Msg::Welcome { proto } => {
            ensure!(
                proto == PROTO_VERSION,
                "leader speaks dist protocol v{proto} (this build speaks v{PROTO_VERSION})"
            );
        }
        Msg::Reject { reason } => bail!("dist: leader rejected this follower: {reason}"),
        m => bail!("dist handshake: expected Welcome/Reject, got {}", m.kind()),
    }
    conn.set_timeout(EXCHANGE_TIMEOUT)?;
    Ok(DistSession { rank, procs: geom.procs, role: Role::Follower { conn } })
}

// ---------------------------------------------------------------------------
// DistSession
// ---------------------------------------------------------------------------

enum Role {
    /// Peer connections sorted by rank (1..procs).
    Leader { peers: Vec<Conn> },
    Follower { conn: Conn },
}

/// A connected process group, attached to a `TrainSession` via
/// `attach_dist`. Owns the sockets; the update-loop exchange and the
/// final shutdown broadcast go through here.
pub struct DistSession {
    rank: usize,
    procs: usize,
    role: Role,
}

impl DistSession {
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn procs(&self) -> usize {
        self.procs
    }

    pub fn is_leader(&self) -> bool {
        matches!(self.role, Role::Leader { .. })
    }

    /// This process's contiguous slot group: `(local_slots, offset)` with
    /// global slot = `offset + local_slot`.
    pub fn slot_range(&self, accum: usize) -> (usize, usize) {
        let local = accum / self.procs;
        (local, self.rank * local)
    }

    /// One update's gradient exchange. `local` holds this process's slot
    /// leaves in slot order. On the leader: fold own + every follower's
    /// leaves in global slot order (the ADR-004 left-deep tree), scale by
    /// `1/accum`, broadcast, return the fold. On a follower: send leaves,
    /// return the leader's broadcast. Errors are [`PeerLost`] (peer died
    /// / desynchronized) or [`Stopped`] (leader-initiated shutdown).
    pub fn exchange(&mut self, step: u64, local: Vec<Leaf>) -> Result<Reduced> {
        let accum = local.len() * self.procs;
        match &mut self.role {
            Role::Leader { peers } => {
                let mut it = local.into_iter();
                let first = it.next().context("dist exchange with zero local slots")?;
                let mut grad = first.grad;
                let mut loss_sum = first.loss as f64;
                let mut acc_sum = first.acc;
                let mut cost_sum = first.cost;
                let mut examples = first.examples;
                let mut fold = |leaf: Leaf, rank: usize| -> Result<()> {
                    ensure!(
                        leaf.grad.trunk.len() == grad.trunk.len()
                            && leaf.grad.head_w.len() == grad.head_w.len()
                            && leaf.grad.head_b.len() == grad.head_b.len(),
                        "dist: rank {rank} sent a gradient leaf of different shape"
                    );
                    grad.axpy(1.0, &leaf.grad);
                    loss_sum += leaf.loss as f64;
                    acc_sum += leaf.acc;
                    cost_sum += leaf.cost;
                    examples += leaf.examples;
                    Ok(())
                };
                for leaf in it {
                    fold(leaf, 0)?;
                }
                for peer in peers.iter_mut() {
                    let rank = peer.rank;
                    let lost = |detail: String| {
                        anyhow::Error::new(PeerLost { rank, detail })
                    };
                    let msg = peer.recv().map_err(|e| lost(format!("{e:#}")))?;
                    let (s, r, leaves) = match msg {
                        Msg::Leaves { step, rank, leaves } => (step, rank, leaves),
                        m => return Err(lost(format!("expected Leaves, got {}", m.kind()))),
                    };
                    if s != step || r as usize != rank || leaves.len() * self.procs != accum {
                        return Err(lost(format!(
                            "desynchronized: sent step {s} rank {r} with {} leaves \
                             (expected step {step} rank {rank} with {} leaves)",
                            leaves.len(),
                            accum / self.procs
                        )));
                    }
                    for leaf in leaves {
                        fold(leaf, rank)?;
                    }
                }
                grad.scale(1.0 / accum as f32);
                let reduced =
                    Reduced { step, grad, loss_sum, acc_sum, cost_sum, examples };
                for peer in peers.iter_mut() {
                    let rank = peer.rank;
                    peer.send(&Msg::Reduced(reduced.clone())).map_err(|e| {
                        anyhow::Error::new(PeerLost { rank, detail: format!("{e:#}") })
                    })?;
                }
                Ok(reduced)
            }
            Role::Follower { conn } => {
                let rank = self.rank;
                let lost =
                    |detail: String| anyhow::Error::new(PeerLost { rank: 0, detail });
                conn.send(&Msg::Leaves { step, rank: rank as u32, leaves: local })
                    .map_err(|e| lost(format!("{e:#}")))?;
                match conn.recv().map_err(|e| lost(format!("{e:#}")))? {
                    Msg::Reduced(r) => {
                        if r.step != step {
                            return Err(lost(format!(
                                "desynchronized: leader reduced step {} (expected {step})",
                                r.step
                            )));
                        }
                        Ok(r)
                    }
                    Msg::Shutdown { code, reason } => {
                        Err(anyhow::Error::new(Stopped { code, reason }))
                    }
                    m => Err(lost(format!("expected Reduced, got {}", m.kind()))),
                }
            }
        }
    }

    /// Leader: broadcast a final [`wire::Msg::Shutdown`] to every
    /// follower, best-effort (a follower that already exited at its own
    /// `max_steps` boundary has closed its socket — that is fine). No-op
    /// on followers.
    pub fn finish(&mut self, code: u8, reason: &str) {
        if let Role::Leader { peers } = &mut self.role {
            for peer in peers.iter_mut() {
                let _ = peer.send(&Msg::Shutdown { code, reason: reason.to_string() });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::reduce;

    fn leaf(seed: u64, n: usize) -> Leaf {
        let mut rng = crate::util::rng::Pcg64::seeded(seed);
        let mut grad = FlatGrad {
            trunk: vec![0.0; n],
            head_w: vec![0.0; 3],
            head_b: vec![0.0; 2],
        };
        rng.fill_normal(&mut grad.trunk, 1.0);
        rng.fill_normal(&mut grad.head_w, 1.0);
        rng.fill_normal(&mut grad.head_b, 1.0);
        Leaf {
            grad,
            loss: rng.next_f32(),
            acc: rng.next_f64(),
            cost: 3.0,
            examples: 8,
        }
    }

    fn geom(fp: u64) -> Geometry {
        Geometry { fingerprint: fp, procs: 2, accum: 4, seed: 7 }
    }

    fn pair(
        leader_geom: Geometry,
        follower_geom: Geometry,
    ) -> (Result<DistSession>, Result<DistSession>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let g = follower_geom;
        let follower = std::thread::spawn(move || connect(&addr, 1, &g));
        let leader = accept_followers(&listener, &leader_geom, || Ok(()));
        (leader, follower.join().unwrap())
    }

    #[test]
    fn handshake_pairs_matching_geometry() {
        let (leader, follower) = pair(geom(1), geom(1));
        let leader = leader.unwrap();
        let follower = follower.unwrap();
        assert!(leader.is_leader());
        assert!(!follower.is_leader());
        assert_eq!(leader.slot_range(4), (2, 0));
        assert_eq!(follower.slot_range(4), (2, 2));
    }

    #[test]
    fn handshake_hard_errors_on_fingerprint_mismatch() {
        let (leader, follower) = pair(geom(1), geom(2));
        let le = format!("{:#}", leader.unwrap_err());
        assert!(le.contains("fingerprint"), "{le}");
        let fe = format!("{:#}", follower.unwrap_err());
        assert!(fe.contains("rejected") && fe.contains("fingerprint"), "{fe}");
    }

    #[test]
    fn handshake_hard_errors_on_geometry_mismatch() {
        let mut other = geom(1);
        other.accum = 8;
        let (leader, follower) = pair(geom(1), other);
        assert!(format!("{:#}", leader.unwrap_err()).contains("geometry"));
        assert!(follower.is_err());
    }

    /// The distributed fold must be bit-identical to the single-process
    /// left-deep fold over the same slot-ordered leaves — the core
    /// determinism claim of ADR-010, checked here at the library level
    /// without any artifacts.
    #[test]
    fn exchange_fold_matches_single_process_tree_bitwise() {
        let leaves: Vec<Leaf> = (0..4).map(|i| leaf(100 + i, 33)).collect();
        let mut want = reduce::tree_reduce_grads(
            leaves.iter().map(|l| l.grad.clone()).collect(),
        )
        .unwrap();
        want.scale(1.0 / 4.0);
        let want_loss: f64 = leaves.iter().map(|l| l.loss as f64).sum();

        let (leader, follower) = pair(geom(1), geom(1));
        let mut leader = leader.unwrap();
        let mut follower = follower.unwrap();
        let (own, remote) = (leaves[..2].to_vec(), leaves[2..].to_vec());
        let follower_thread = std::thread::spawn(move || {
            let r = follower.exchange(9, remote).unwrap();
            (follower, r)
        });
        let got = leader.exchange(9, own).unwrap();
        let (_, follower_got) = follower_thread.join().unwrap();

        for g in [&got.grad, &follower_got.grad] {
            assert_eq!(g.trunk, want.trunk);
            assert_eq!(g.head_w, want.head_w);
            assert_eq!(g.head_b, want.head_b);
        }
        assert_eq!(got.loss_sum.to_bits(), want_loss.to_bits());
        assert_eq!(got.loss_sum.to_bits(), follower_got.loss_sum.to_bits());
        assert_eq!(got.examples, 32);
    }

    #[test]
    fn follower_sees_stopped_after_leader_finish() {
        let (leader, follower) = pair(geom(1), geom(1));
        let mut leader = leader.unwrap();
        let mut follower = follower.unwrap();
        leader.finish(SHUTDOWN_INTERRUPTED, "sigint");
        let err = follower.exchange(0, vec![leaf(1, 4), leaf(2, 4)]).unwrap_err();
        let stopped = err.downcast_ref::<Stopped>().expect("Stopped error");
        assert_eq!(stopped.code, SHUTDOWN_INTERRUPTED);
        assert_eq!(stopped.reason, "sigint");
    }

    #[test]
    fn dead_follower_surfaces_as_peer_lost() {
        let (leader, follower) = pair(geom(1), geom(1));
        let mut leader = leader.unwrap();
        drop(follower.unwrap()); // follower "dies": socket closes
        let err = leader.exchange(0, vec![leaf(1, 4), leaf(2, 4)]).unwrap_err();
        let lost = err.downcast_ref::<PeerLost>().expect("PeerLost error");
        assert_eq!(lost.rank, 1);
    }

    #[test]
    fn desynchronized_step_is_peer_lost() {
        let (leader, follower) = pair(geom(1), geom(1));
        let mut leader = leader.unwrap();
        let mut follower = follower.unwrap();
        let t = std::thread::spawn(move || {
            // Follower thinks it is on step 3; leader expects step 2.
            let _ = follower.exchange(3, vec![leaf(1, 4), leaf(2, 4)]);
        });
        let err = leader.exchange(2, vec![leaf(3, 4), leaf(4, 4)]).unwrap_err();
        assert!(err.downcast_ref::<PeerLost>().is_some(), "{err:#}");
        assert!(format!("{err:#}").contains("desynchronized"), "{err:#}");
        // Close the leader's sockets so the follower's pending recv
        // unblocks before we join it.
        drop(leader);
        t.join().unwrap();
    }
}
