//! Length-prefixed, CRC-framed wire protocol for the distributed runner
//! (DESIGN.md ADR-010).
//!
//! Every message travels as one frame:
//!
//! ```text
//! payload_len u32 | payload_crc u32 | payload
//! ```
//!
//! with `payload_crc` the ADR-008 CRC32 of the payload bytes, so a
//! corrupted or desynchronized stream reads as a structured error, never
//! as a garbled message. The payload is a one-byte tag followed by a body
//! in the checkpoint codec (`checkpoint::{Enc, Dec}`, little-endian) —
//! the same encoding the `.lgpckpt` artifacts use, so the wire and disk
//! formats cannot drift apart in how they serialize tensors.
//!
//! The handshake is version-negotiated and fingerprint-checked: a
//! follower opens with [`Hello`] carrying [`PROTO_VERSION`] and the
//! ADR-008 config/manifest fingerprint; the leader replies [`Msg::Welcome`]
//! or [`Msg::Reject`] with a reason. A fingerprint or geometry mismatch is
//! a hard error on both sides — resuming a different experiment's stream
//! would silently diverge, exactly the failure ADR-008 fingerprints exist
//! to prevent.

use crate::checkpoint::{crc32, Dec, Enc};
use crate::model::params::FlatGrad;
use anyhow::{bail, ensure, Context as _, Result};
use std::io::{Read, Write};

/// Wire protocol version; bumped on any incompatible message change.
/// Peers with different versions refuse to pair during the handshake.
pub const PROTO_VERSION: u32 = 1;

/// Upper bound on one frame's payload. Gradient-leaf frames scale with
/// `accum/procs × total_params`; 256 MiB is far above any manifest this
/// repo ships while still bounding the allocation a corrupt length
/// prefix can demand.
pub const MAX_FRAME_BYTES: usize = 256 << 20;

/// Shutdown codes carried by [`Msg::Shutdown`] (leader → follower).
pub const SHUTDOWN_COMPLETE: u8 = 0;
pub const SHUTDOWN_INTERRUPTED: u8 = 1;
pub const SHUTDOWN_ERROR: u8 = 2;

// ---------------------------------------------------------------------------
// Framing
// ---------------------------------------------------------------------------

/// Write one frame (length prefix + CRC + payload) and flush.
pub fn send_frame(w: &mut impl Write, payload: &[u8]) -> Result<()> {
    ensure!(
        payload.len() <= MAX_FRAME_BYTES,
        "dist frame of {} bytes exceeds the {} byte limit",
        payload.len(),
        MAX_FRAME_BYTES
    );
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(&crc32(payload).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one frame, verifying the length bound and the payload CRC.
pub fn recv_frame(r: &mut impl Read) -> Result<Vec<u8>> {
    let mut head = [0u8; 8];
    r.read_exact(&mut head).context("dist: reading frame header")?;
    let len = u32::from_le_bytes(head[..4].try_into().unwrap()) as usize;
    let want_crc = u32::from_le_bytes(head[4..8].try_into().unwrap());
    ensure!(
        len <= MAX_FRAME_BYTES,
        "dist frame header claims {len} bytes (limit {MAX_FRAME_BYTES}) — corrupt or hostile peer"
    );
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload).context("dist: reading frame payload")?;
    ensure!(crc32(&payload) == want_crc, "dist frame corrupt (payload crc mismatch)");
    Ok(payload)
}

// ---------------------------------------------------------------------------
// Messages
// ---------------------------------------------------------------------------

/// Follower's opening message: everything the leader must agree on
/// before a single gradient crosses the wire.
#[derive(Clone, Debug, PartialEq)]
pub struct Hello {
    pub proto: u32,
    /// ADR-008 config/manifest fingerprint of the follower's session.
    pub fingerprint: u64,
    pub rank: u32,
    pub procs: u32,
    /// Global micro-batch slot count (`--accum`); every process must see
    /// the same value for the slot partition to tile the update.
    pub accum: u32,
    /// Data-stream seed; redundant with the fingerprint but cheap to
    /// check and names the mismatch precisely.
    pub seed: u64,
}

/// One micro-batch slot's contribution: the gradient leaf plus the
/// scalar traces the coordinator folds in slot order (ADR-004).
#[derive(Clone, Debug)]
pub struct Leaf {
    pub grad: FlatGrad,
    pub loss: f32,
    pub acc: f64,
    pub cost: f64,
    pub examples: u64,
}

/// The leader's folded update, broadcast so every process applies the
/// bit-identical optimizer step.
#[derive(Clone, Debug)]
pub struct Reduced {
    pub step: u64,
    /// Mean gradient: the full left-deep fold over all `accum` leaves,
    /// already scaled by `1/accum` on the leader.
    pub grad: FlatGrad,
    pub loss_sum: f64,
    pub acc_sum: f64,
    pub cost_sum: f64,
    pub examples: u64,
}

/// Every message that crosses a dist socket.
#[derive(Clone, Debug)]
pub enum Msg {
    Hello(Hello),
    Welcome { proto: u32 },
    Reject { reason: String },
    Leaves { step: u64, rank: u32, leaves: Vec<Leaf> },
    Reduced(Reduced),
    Shutdown { code: u8, reason: String },
}

const TAG_HELLO: u8 = 1;
const TAG_WELCOME: u8 = 2;
const TAG_REJECT: u8 = 3;
const TAG_LEAVES: u8 = 4;
const TAG_REDUCED: u8 = 5;
const TAG_SHUTDOWN: u8 = 6;

fn put_flat(e: &mut Enc, g: &FlatGrad) {
    e.put_f32s(&g.trunk);
    e.put_f32s(&g.head_w);
    e.put_f32s(&g.head_b);
}

fn take_flat(d: &mut Dec) -> Result<FlatGrad> {
    Ok(FlatGrad { trunk: d.take_f32s()?, head_w: d.take_f32s()?, head_b: d.take_f32s()? })
}

impl Msg {
    pub fn encode(&self) -> Vec<u8> {
        let mut e = Enc::new();
        match self {
            Msg::Hello(h) => {
                e.put_u8(TAG_HELLO);
                e.put_u32(h.proto);
                e.put_u64(h.fingerprint);
                e.put_u32(h.rank);
                e.put_u32(h.procs);
                e.put_u32(h.accum);
                e.put_u64(h.seed);
            }
            Msg::Welcome { proto } => {
                e.put_u8(TAG_WELCOME);
                e.put_u32(*proto);
            }
            Msg::Reject { reason } => {
                e.put_u8(TAG_REJECT);
                e.put_str(reason);
            }
            Msg::Leaves { step, rank, leaves } => {
                e.put_u8(TAG_LEAVES);
                e.put_u64(*step);
                e.put_u32(*rank);
                e.put_u32(leaves.len() as u32);
                for l in leaves {
                    e.put_f32(l.loss);
                    e.put_f64(l.acc);
                    e.put_f64(l.cost);
                    e.put_u64(l.examples);
                    put_flat(&mut e, &l.grad);
                }
            }
            Msg::Reduced(r) => {
                e.put_u8(TAG_REDUCED);
                e.put_u64(r.step);
                e.put_f64(r.loss_sum);
                e.put_f64(r.acc_sum);
                e.put_f64(r.cost_sum);
                e.put_u64(r.examples);
                put_flat(&mut e, &r.grad);
            }
            Msg::Shutdown { code, reason } => {
                e.put_u8(TAG_SHUTDOWN);
                e.put_u8(*code);
                e.put_str(reason);
            }
        }
        e.into_bytes()
    }

    pub fn decode(bytes: &[u8]) -> Result<Msg> {
        let mut d = Dec::new(bytes, "dist message");
        let tag = d.take_u8()?;
        let msg = match tag {
            TAG_HELLO => Msg::Hello(Hello {
                proto: d.take_u32()?,
                fingerprint: d.take_u64()?,
                rank: d.take_u32()?,
                procs: d.take_u32()?,
                accum: d.take_u32()?,
                seed: d.take_u64()?,
            }),
            TAG_WELCOME => Msg::Welcome { proto: d.take_u32()? },
            TAG_REJECT => Msg::Reject { reason: d.take_str()? },
            TAG_LEAVES => {
                let step = d.take_u64()?;
                let rank = d.take_u32()?;
                let n = d.take_u32()? as usize;
                let mut leaves = Vec::with_capacity(n.min(4096));
                for _ in 0..n {
                    let loss = d.take_f32()?;
                    let acc = d.take_f64()?;
                    let cost = d.take_f64()?;
                    let examples = d.take_u64()?;
                    let grad = take_flat(&mut d)?;
                    leaves.push(Leaf { grad, loss, acc, cost, examples });
                }
                Msg::Leaves { step, rank, leaves }
            }
            TAG_REDUCED => {
                let step = d.take_u64()?;
                let loss_sum = d.take_f64()?;
                let acc_sum = d.take_f64()?;
                let cost_sum = d.take_f64()?;
                let examples = d.take_u64()?;
                let grad = take_flat(&mut d)?;
                Msg::Reduced(Reduced { step, grad, loss_sum, acc_sum, cost_sum, examples })
            }
            TAG_SHUTDOWN => Msg::Shutdown { code: d.take_u8()?, reason: d.take_str()? },
            t => bail!("dist message with unknown tag {t} (peer speaks a newer protocol?)"),
        };
        d.finish()?;
        Ok(msg)
    }

    /// Short name for diagnostics ("expected Reduced, got Shutdown").
    pub fn kind(&self) -> &'static str {
        match self {
            Msg::Hello(_) => "Hello",
            Msg::Welcome { .. } => "Welcome",
            Msg::Reject { .. } => "Reject",
            Msg::Leaves { .. } => "Leaves",
            Msg::Reduced(_) => "Reduced",
            Msg::Shutdown { .. } => "Shutdown",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grad(seed: f32) -> FlatGrad {
        FlatGrad {
            trunk: vec![seed, seed + 0.5, -seed],
            head_w: vec![2.0 * seed],
            head_b: vec![-0.25],
        }
    }

    fn roundtrip(m: &Msg) -> Msg {
        let mut buf = Vec::new();
        send_frame(&mut buf, &m.encode()).unwrap();
        let payload = recv_frame(&mut buf.as_slice()).unwrap();
        Msg::decode(&payload).unwrap()
    }

    #[test]
    fn every_message_kind_round_trips_through_a_frame() {
        let hello = Msg::Hello(Hello {
            proto: PROTO_VERSION,
            fingerprint: 0xfeed_beef_dead_cafe,
            rank: 1,
            procs: 2,
            accum: 4,
            seed: 7,
        });
        match roundtrip(&hello) {
            Msg::Hello(h) => {
                assert_eq!(h.fingerprint, 0xfeed_beef_dead_cafe);
                assert_eq!((h.rank, h.procs, h.accum, h.seed), (1, 2, 4, 7));
            }
            m => panic!("got {}", m.kind()),
        }
        let leaves = Msg::Leaves {
            step: 42,
            rank: 1,
            leaves: vec![
                Leaf { grad: grad(1.0), loss: 0.5, acc: 0.75, cost: 3.0, examples: 8 },
                Leaf { grad: grad(-2.0), loss: 1.5, acc: 0.25, cost: 3.0, examples: 8 },
            ],
        };
        match roundtrip(&leaves) {
            Msg::Leaves { step, rank, leaves } => {
                assert_eq!((step, rank), (42, 1));
                assert_eq!(leaves.len(), 2);
                assert_eq!(leaves[0].grad.trunk, grad(1.0).trunk);
                assert_eq!(leaves[1].loss.to_bits(), 1.5f32.to_bits());
            }
            m => panic!("got {}", m.kind()),
        }
        let red = Msg::Reduced(Reduced {
            step: 42,
            grad: grad(0.125),
            loss_sum: 2.0,
            acc_sum: 1.0,
            cost_sum: 6.0,
            examples: 16,
        });
        match roundtrip(&red) {
            Msg::Reduced(r) => {
                assert_eq!(r.grad.trunk, grad(0.125).trunk);
                assert_eq!(r.examples, 16);
            }
            m => panic!("got {}", m.kind()),
        }
        for m in [
            Msg::Welcome { proto: PROTO_VERSION },
            Msg::Reject { reason: "fingerprint mismatch".into() },
            Msg::Shutdown { code: SHUTDOWN_INTERRUPTED, reason: "sigint".into() },
        ] {
            assert_eq!(roundtrip(&m).kind(), m.kind());
        }
    }

    #[test]
    fn corrupt_frames_are_structured_errors() {
        let msg = Msg::Welcome { proto: PROTO_VERSION };
        let mut buf = Vec::new();
        send_frame(&mut buf, &msg.encode()).unwrap();
        // Flip one payload byte: CRC must catch it.
        let n = buf.len();
        let mut bad = buf.clone();
        bad[n - 1] ^= 0x10;
        let err = recv_frame(&mut bad.as_slice()).unwrap_err();
        assert!(format!("{err:#}").contains("crc mismatch"), "{err:#}");
        // Oversized length prefix: rejected before allocating.
        let mut huge = buf.clone();
        huge[..4].copy_from_slice(&(u32::MAX).to_le_bytes());
        let err = recv_frame(&mut huge.as_slice()).unwrap_err();
        assert!(format!("{err:#}").contains("limit"), "{err:#}");
        // Truncated stream: structured read error.
        assert!(recv_frame(&mut buf[..5].to_vec().as_slice()).is_err());
    }

    #[test]
    fn unknown_tag_and_trailing_bytes_are_rejected() {
        assert!(Msg::decode(&[99]).is_err());
        let mut bytes = Msg::Welcome { proto: 1 }.encode();
        bytes.push(0);
        let err = Msg::decode(&bytes).unwrap_err();
        assert!(format!("{err:#}").contains("trailing"), "{err:#}");
    }
}
