//! lgp — Linear Gradient Prediction with Control Variates.
//!
//! Full-system reproduction of Ciosek, Felicioni & Elenter Litwin (2025):
//! a Rust training coordinator (Layer 3) driving AOT-compiled JAX/Pallas
//! compute artifacts (Layers 2/1) through the PJRT C API, with the paper's
//! predicted-gradient-descent algorithm, NTK-inspired linear gradient
//! predictor, control-variate debiasing, and the Section 5 theory.
//!
//! See DESIGN.md for the architecture and EXPERIMENTS.md for the
//! paper-vs-measured record.

// Debug-only allocation counter (feature `alloc-counter`): installing the
// hook here makes every allocation in the process visible to
// `util::alloc_track::alloc_count`, which the zero-allocation hot-path
// test asserts against (ADR-003).
#[cfg(feature = "alloc-counter")]
#[global_allocator]
static GLOBAL_ALLOC_COUNTER: util::alloc_track::CountingAllocator =
    util::alloc_track::CountingAllocator;

pub mod bench_support;
pub mod coordinator;
pub mod config;
pub mod data;
pub mod metrics;
pub mod runtime;
pub mod model;
pub mod optim;
pub mod predictor;
pub mod tensor;
pub mod theory;
pub mod util;
