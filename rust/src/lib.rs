//! lgp — Linear Gradient Prediction with Control Variates.
//!
//! Full-system reproduction of Ciosek, Felicioni & Elenter Litwin (2025):
//! a Rust training coordinator (Layer 3) driving AOT-compiled JAX/Pallas
//! compute artifacts (Layers 2/1) through the PJRT C API, with the paper's
//! predicted-gradient-descent algorithm, NTK-inspired linear gradient
//! predictor, control-variate debiasing, and the Section 5 theory.
//!
//! The public API is library-first (DESIGN.md ADR-005): configure a run
//! with [`session::SessionBuilder`], pick a [`estimator::GradientEstimator`]
//! (or let `algo`/`f` pick one), attach [`observer::TrainObserver`] sinks,
//! and drive the immutable [`session::TrainSession`]. Everything the CLI
//! does goes through the same builder. Start with [`prelude`]:
//!
//! ```no_run
//! use lgp::prelude::*;
//!
//! # fn main() -> anyhow::Result<()> {
//! let mut session = SessionBuilder::new().preset("tiny").max_steps(10).build()?;
//! session.run()?;
//! # Ok(())
//! # }
//! ```
//!
//! See DESIGN.md for the architecture and EXPERIMENTS.md for the
//! paper-vs-measured record.

// Debug-only allocation counter (feature `alloc-counter`): installing the
// hook here makes every allocation in the process visible to
// `util::alloc_track::alloc_count`, which the zero-allocation hot-path
// test asserts against (ADR-003).
#[cfg(feature = "alloc-counter")]
#[global_allocator]
static GLOBAL_ALLOC_COUNTER: util::alloc_track::CountingAllocator =
    util::alloc_track::CountingAllocator;

pub mod bench_support;
pub mod checkpoint;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod dist;
pub mod estimator;
pub mod metrics;
pub mod model;
pub mod observer;
pub mod optim;
pub mod predictor;
pub mod runtime;
pub mod serve;
pub mod session;
pub mod tensor;
pub mod theory;
pub mod util;

/// One-stop imports for the library-first API (ADR-005): the session
/// builder, the shipped estimators and observers, and the config enums
/// their setters take.
pub mod prelude {
    pub use crate::config::{Algo, EstimatorKind, OptimKind, RunConfig};
    pub use crate::estimator::{
        ControlVariate, GradientEstimator, MultiTangentForward, NeuralControlVariate,
        PredictedLgp, TrueBackprop, UpdatePlan,
    };
    pub use crate::metrics::{Alignment, LogRow};
    pub use crate::observer::{
        CsvObserver, DistEvent, DistEventKind, JsonlObserver, Multicast, RefitEvent,
        RunSummary, TrainObserver,
    };
    pub use crate::session::{SessionBuilder, TrainSession};
    pub use crate::tensor::BackendKind;
    pub use crate::theory::CostModel;
}
