//! Persistent parked worker pool (DESIGN.md ADR-007): the successor to
//! per-update `std::thread::scope` scatter in [`super::exec`].
//!
//! [`super::exec::scatter`] spawns and joins OS threads on **every**
//! update, an overhead (~60–120µs per spawn on this class of host) that
//! scales with update count and dwarfs small dispatch workloads. A
//! [`WorkerPool`] spawns its threads once — at session build — and parks
//! them on a per-thread condvar between dispatches, so steady-state
//! dispatch cost is two mutex hops per worker and zero allocations.
//!
//! The pool preserves the ADR-004 determinism contract exactly: slot
//! assignment is the same round-robin pure function
//! ([`super::exec::worker_of_slot`]), results land in a slot-indexed
//! array, the serial path (one worker or one slot) runs inline on the
//! caller thread, and the lowest-indexed failing worker's error wins.
//! `pool.scatter` is bit-identical to `exec::scatter` for any task.
//!
//! On top of the generic scatter the pool parallelizes *single large
//! kernels* across workers ([`WorkerPool::matmul_into_ws`],
//! [`WorkerPool::gram_t_into_ws`]): the output is split into contiguous
//! row bands, each band computed by `Backend::matmul_rows` /
//! `Backend::gram_t_rows`. Those primitives carry a banding contract
//! (see `tensor::backend`): a band's rows are bitwise identical to the
//! same rows of a full serial call under any partition, so the pooled
//! kernels stay bit-identical to serial and `--shards N` determinism
//! survives intra-shard parallelism.
//!
//! # Safety model (the `unsafe` in this file)
//!
//! Dispatch hands workers a raw pointer to a stack-allocated, type-erased
//! [`JobHeader`] (first field of a `#[repr(C)]` `Job<W, T, F>` carrying
//! the real pointers: worker slice, output slab, task closure, error and
//! panic sinks). This is sound because the dispatching thread **blocks
//! inside the same `scatter` call until every worker has signalled
//! completion** — the job, the worker slice and the output slab outlive
//! every dereference, and each worker touches only its own round-robin
//! slots (disjoint `&mut` access by construction, exactly as in the
//! scoped-thread version). Worker panics are caught, parked in a sink,
//! and re-thrown on the dispatching thread after the barrier — the pool
//! itself survives and stays usable. Outputs are written into a
//! `MaybeUninit<T>` slab; on failure, per-worker completion counters
//! (published with `Release`, read after the completion barrier) say
//! exactly which slots were initialized and must be dropped.

use std::any::Any;
use std::mem::MaybeUninit;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use super::exec;
use crate::tensor::backend::mirror_upper;
use crate::tensor::{Backend, Tensor, Workspace};

/// Minimum FLOP count (2·m·k·n for matmul, n·d² for gram) before a kernel
/// is worth banding across workers: below this the ~two-mutex-hop wakeup
/// per worker is a measurable fraction of the kernel itself.
const PAR_MIN_FLOPS: usize = 1 << 19;

/// What a parked worker thread is being asked to do. The raw job pointer
/// is only ever dereferenced while the dispatching thread blocks in the
/// same `scatter` call (see module docs), which is what makes the manual
/// `Send` sound.
enum Cmd {
    Idle,
    Run { job: *const JobHeader, worker: usize },
    Exit,
}

// SAFETY: `Cmd::Run`'s pointer is created by `scatter`, which keeps the
// pointee alive and blocks until the worker is done with it.
unsafe impl Send for Cmd {}

/// Type-erased entry of a dispatched job: first (and only) field read by
/// worker threads, which re-derive the concrete `Job<W, T, F>` through
/// the monomorphized `run` they were handed.
#[repr(C)]
struct JobHeader {
    run: unsafe fn(*const JobHeader, usize),
}

/// The concrete, fully-typed job, stack-allocated in `scatter`.
/// `#[repr(C)]` with `header` first so a `*const JobHeader` round-trips
/// to `*const Job<W, T, F>`.
#[repr(C)]
struct Job<W, T, F> {
    header: JobHeader,
    workers: *mut W,
    /// Effective worker count; worker `w` owns slots `{s : s % n == w}`.
    n: usize,
    slots: usize,
    outs: *mut MaybeUninit<T>,
    task: *const F,
    err: *const Mutex<Option<(usize, anyhow::Error)>>,
    panic: *const Mutex<Option<Box<dyn Any + Send>>>,
    /// Per-worker count of slots successfully written (len ≥ n).
    completed: *const AtomicUsize,
}

/// Monomorphized worker body: run worker `w`'s round-robin slots of the
/// job behind `header`.
///
/// # Safety
/// `header` must point at the `JobHeader` of a live `Job<W, T, F>` whose
/// pointers are all valid for the duration of the call, and no other
/// thread may touch worker `w`'s state or slots concurrently — both
/// guaranteed by `scatter`'s dispatch/barrier protocol.
unsafe fn run_one<W, T, F>(header: *const JobHeader, w: usize)
where
    F: Fn(&mut W, usize) -> anyhow::Result<T>,
{
    let job = &*(header as *const Job<W, T, F>);
    let task = &*job.task;
    let worker = &mut *job.workers.add(w);
    let completed = &*job.completed.add(w);
    let outcome = catch_unwind(AssertUnwindSafe(|| {
        let mut done = 0usize;
        let mut slot = w;
        while slot < job.slots {
            match task(worker, slot) {
                Ok(v) => {
                    std::ptr::write(job.outs.add(slot), MaybeUninit::new(v));
                    done += 1;
                    completed.store(done, Ordering::Release);
                }
                Err(e) => {
                    let mut guard = (*job.err).lock().unwrap();
                    if guard.as_ref().map_or(true, |(we, _)| w < *we) {
                        *guard = Some((w, e));
                    }
                    return;
                }
            }
            slot += job.n;
        }
    }));
    if let Err(p) = outcome {
        let mut guard = (*job.panic).lock().unwrap();
        if guard.is_none() {
            *guard = Some(p);
        }
    }
}

/// One parked worker thread's mailbox.
struct WorkerSlot {
    cmd: Mutex<Cmd>,
    cv: Condvar,
}

/// Completion barrier: how many background workers of the current
/// dispatch are still running.
struct DoneGate {
    remaining: Mutex<usize>,
    cv: Condvar,
}

struct PoolThread {
    slot: Arc<WorkerSlot>,
    handle: Option<std::thread::JoinHandle<()>>,
}

fn worker_loop(slot: Arc<WorkerSlot>, gate: Arc<DoneGate>) {
    loop {
        let (job, worker) = {
            let mut cmd = slot.cmd.lock().unwrap();
            loop {
                match *cmd {
                    Cmd::Run { job, worker } => {
                        *cmd = Cmd::Idle;
                        break (job, worker);
                    }
                    Cmd::Exit => return,
                    Cmd::Idle => cmd = slot.cv.wait(cmd).unwrap(),
                }
            }
        };
        // SAFETY: the dispatcher keeps the job alive until the gate
        // reaches zero, which only happens after this call returns.
        unsafe { ((*job).run)(job, worker) };
        let mut remaining = gate.remaining.lock().unwrap();
        *remaining -= 1;
        if *remaining == 0 {
            gate.cv.notify_all();
        }
    }
}

/// Raw `f32` base pointer that may cross threads: the banded kernels
/// hand each worker a disjoint row range of one output buffer.
#[derive(Clone, Copy)]
struct SendPtr(*mut f32);
// SAFETY: workers write disjoint `[r0*stride, r1*stride)` ranges (one
// band per slot, each slot dispatched exactly once).
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// Persistent worker pool: `width - 1` parked threads plus the calling
/// thread (worker 0). Spawn once per [`crate::session::TrainSession`],
/// reuse for every update (ADR-007).
pub struct WorkerPool {
    width: usize,
    threads: Vec<PoolThread>,
    gate: Arc<DoneGate>,
    /// Non-reentrant dispatch guard: one job in flight at a time. Held
    /// across dispatch + completion barrier; band kernels must not be
    /// called from inside a pool task (documented invariant).
    dispatch: Mutex<()>,
    err: Mutex<Option<(usize, anyhow::Error)>>,
    panic: Mutex<Option<Box<dyn Any + Send>>>,
    /// Pre-allocated per-worker completion counters (no per-dispatch
    /// allocation; the alloc-free satellite test pins this).
    completed: Vec<AtomicUsize>,
    /// Per-worker scratch arenas for the banded kernels; locked by the
    /// owning band task on its worker thread.
    wss: Vec<Mutex<Workspace>>,
}

impl WorkerPool {
    /// Build a pool sized for `shards` workers (≥ 1). `shards <= 1`
    /// spawns no threads at all — every dispatch takes the inline serial
    /// path, identical to `exec::scatter`.
    pub fn new(shards: usize) -> WorkerPool {
        let width = shards.max(1);
        let gate = Arc::new(DoneGate { remaining: Mutex::new(0), cv: Condvar::new() });
        let mut threads = Vec::with_capacity(width - 1);
        for t in 0..width - 1 {
            let slot = Arc::new(WorkerSlot { cmd: Mutex::new(Cmd::Idle), cv: Condvar::new() });
            let worker_slot = Arc::clone(&slot);
            let worker_gate = Arc::clone(&gate);
            let handle = std::thread::Builder::new()
                .name(format!("lgp-pool-{t}"))
                .spawn(move || worker_loop(worker_slot, worker_gate))
                .expect("spawn pool worker thread");
            threads.push(PoolThread { slot, handle: Some(handle) });
        }
        WorkerPool {
            width,
            threads,
            gate,
            dispatch: Mutex::new(()),
            err: Mutex::new(None),
            panic: Mutex::new(None),
            completed: (0..width).map(|_| AtomicUsize::new(0)).collect(),
            wss: (0..width).map(|_| Mutex::new(Workspace::new())).collect(),
        }
    }

    /// Worker capacity (the configured shard count, min 1).
    pub fn width(&self) -> usize {
        self.width
    }

    /// Scatter `slots` tasks across the pool, gather results in slot
    /// order — drop-in replacement for [`super::exec::scatter`] with the
    /// identical contract (round-robin ownership, slot-ordered results,
    /// lowest-indexed worker's error, panics re-thrown on the caller),
    /// minus the per-call thread spawn. In steady state (warmed caller
    /// buffers, `T` zero-sized or pre-sized) a dispatch performs no heap
    /// allocation.
    pub fn scatter<W, T, F>(
        &self,
        workers: &mut [W],
        slots: usize,
        task: F,
    ) -> anyhow::Result<Vec<T>>
    where
        W: Send,
        T: Send,
        F: Fn(&mut W, usize) -> anyhow::Result<T> + Sync,
    {
        assert!(!workers.is_empty(), "scatter needs at least one worker");
        if slots == 0 {
            return Ok(Vec::new());
        }
        let n = exec::effective_workers(workers.len().min(self.width), slots);
        if n == 1 {
            // Serial fast path: same slot order, no synchronization.
            let w = &mut workers[0];
            let mut out = Vec::with_capacity(slots);
            for slot in 0..slots {
                out.push(task(&mut *w, slot)?);
            }
            return Ok(out);
        }

        let mut outs: Vec<MaybeUninit<T>> = Vec::with_capacity(slots);
        // SAFETY: `MaybeUninit` needs no initialization; every element is
        // either written by its owning worker or never read (failure
        // cleanup walks the completion counters).
        unsafe { outs.set_len(slots) };

        let _dispatch = self.dispatch.lock().unwrap();
        *self.err.lock().unwrap() = None;
        *self.panic.lock().unwrap() = None;
        for c in &self.completed[..n] {
            c.store(0, Ordering::Relaxed);
        }
        *self.gate.remaining.lock().unwrap() = n - 1;

        let job = Job::<W, T, F> {
            header: JobHeader { run: run_one::<W, T, F> },
            workers: workers.as_mut_ptr(),
            n,
            slots,
            outs: outs.as_mut_ptr(),
            task: &task,
            err: &self.err,
            panic: &self.panic,
            completed: self.completed.as_ptr(),
        };
        let header = &job.header as *const JobHeader;
        for w in 1..n {
            let thread = &self.threads[w - 1];
            let mut cmd = thread.slot.cmd.lock().unwrap();
            debug_assert!(matches!(*cmd, Cmd::Idle), "dispatch into a busy worker");
            *cmd = Cmd::Run { job: header, worker: w };
            thread.slot.cv.notify_one();
        }
        // The dispatching thread is worker 0.
        // SAFETY: `job` and everything it points to live on this stack
        // frame / in `self`, and we do not return before the gate says
        // every background worker is done with them.
        unsafe { run_one::<W, T, F>(header, 0) };
        {
            let mut remaining = self.gate.remaining.lock().unwrap();
            while *remaining != 0 {
                remaining = self.gate.cv.wait(remaining).unwrap();
            }
        }

        if let Some(p) = self.panic.lock().unwrap().take() {
            Self::drop_partial(&mut outs, n, &self.completed);
            resume_unwind(p);
        }
        if let Some((_, e)) = self.err.lock().unwrap().take() {
            Self::drop_partial(&mut outs, n, &self.completed);
            return Err(e);
        }
        // Success: every slot initialized by its round-robin owner.
        // SAFETY: `MaybeUninit<T>` has the same layout as `T`.
        let out = unsafe {
            let ptr = outs.as_mut_ptr() as *mut T;
            let (len, cap) = (outs.len(), outs.capacity());
            std::mem::forget(outs);
            Vec::from_raw_parts(ptr, len, cap)
        };
        Ok(out)
    }

    /// Drop the slots that were initialized before a failed dispatch:
    /// worker `w` wrote its first `completed[w]` slots `w, w+n, …`.
    fn drop_partial<T>(outs: &mut [MaybeUninit<T>], n: usize, completed: &[AtomicUsize]) {
        if !std::mem::needs_drop::<T>() {
            return;
        }
        for (w, c) in completed[..n].iter().enumerate() {
            let done = c.load(Ordering::Acquire);
            for i in 0..done {
                // SAFETY: the owner published `done` successful writes.
                unsafe { outs[w + i * n].assume_init_drop() };
            }
        }
    }

    /// C = A @ B with the output row-banded across the pool when the
    /// problem is large enough to amortize the wakeup (ADR-007); serial
    /// `be.matmul_into_ws` otherwise. Bit-identical to the serial call in
    /// both regimes via the backend banding contract.
    pub fn matmul_into_ws(
        &self,
        be: Backend,
        a: &Tensor,
        b: &Tensor,
        c: &mut Tensor,
        ws: &mut Workspace,
    ) {
        let (m, k) = (a.rows(), a.cols());
        let n = b.cols();
        let flops = 2 * m * k * n;
        if self.width < 2 || m < 2 || n == 0 || flops < PAR_MIN_FLOPS {
            be.matmul_into_ws(a, b, c, ws);
            return;
        }
        self.matmul_banded(be, a, b, c);
    }

    /// The always-banded matmul path (tests call this directly to pin
    /// band/serial bitwise identity below the FLOP threshold too).
    fn matmul_banded(&self, be: Backend, a: &Tensor, b: &Tensor, c: &mut Tensor) {
        let (m, k) = (a.rows(), a.cols());
        let (k2, n) = (b.rows(), b.cols());
        assert_eq!(k, k2, "matmul inner-dim mismatch: {k} vs {k2}");
        assert_eq!(c.shape, [m, n], "matmul output shape mismatch");
        let nw = self.width.min(m);
        let per = m.div_ceil(nw);
        let nbands = m.div_ceil(per);
        let base = SendPtr(c.data.as_mut_ptr());
        let wss = &self.wss;
        let mut units = vec![(); nbands];
        self.scatter(&mut units, nbands, move |_u: &mut (), slot| {
            let r0 = slot * per;
            let r1 = (r0 + per).min(m);
            // SAFETY: bands are disjoint row ranges of `c.data` (slot is
            // unique per dispatch), valid while `c` is borrowed above.
            let band =
                unsafe { std::slice::from_raw_parts_mut(base.0.add(r0 * n), (r1 - r0) * n) };
            let mut ws = wss[slot].lock().unwrap();
            be.matmul_rows(a, b, r0, r1, band, &mut ws);
            Ok(())
        })
        .expect("pooled matmul tasks are infallible");
    }

    /// C = A^T @ A with output rows banded across the pool (triangle-
    /// balanced cuts, since row `i` of the fused symmetric kernel only
    /// computes `d - i` cells); serial below the FLOP threshold.
    pub fn gram_t_into_ws(&self, be: Backend, a: &Tensor, c: &mut Tensor, ws: &mut Workspace) {
        let (n, d) = (a.rows(), a.cols());
        let flops = n * d * d;
        if self.width < 2 || d < 2 || flops < PAR_MIN_FLOPS {
            be.gram_t_into_ws(a, c, ws);
            return;
        }
        self.gram_t_banded(be, a, c);
    }

    fn gram_t_banded(&self, be: Backend, a: &Tensor, c: &mut Tensor) {
        let d = a.cols();
        assert_eq!(c.shape, [d, d], "gram_t output shape mismatch");
        let nw = self.width.min(d);
        let base = SendPtr(c.data.as_mut_ptr());
        let wss = &self.wss;
        let mut units = vec![(); nw];
        self.scatter(&mut units, nw, move |_u: &mut (), slot| {
            let r0 = tri_cut(d, nw, slot);
            let r1 = tri_cut(d, nw, slot + 1);
            // SAFETY: `tri_cut` is monotone in `slot`, so bands are
            // disjoint row ranges of `c.data`.
            let band =
                unsafe { std::slice::from_raw_parts_mut(base.0.add(r0 * d), (r1 - r0) * d) };
            let mut ws = wss[slot].lock().unwrap();
            be.gram_t_rows(a, r0, r1, band, &mut ws);
            Ok(())
        })
        .expect("pooled gram_t tasks are infallible");
        mirror_upper(c, d);
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        for t in &self.threads {
            let mut cmd = t.slot.cmd.lock().unwrap();
            *cmd = Cmd::Exit;
            t.slot.cv.notify_one();
        }
        for t in &mut self.threads {
            if let Some(h) = t.handle.take() {
                let _ = h.join();
            }
        }
    }
}

/// Row boundary `b` of `parts` triangle-balanced contiguous bands over
/// the `d`-row upper-triangular gram workload: the smallest `i` whose
/// cumulative cell count `i·d − i(i−1)/2` reaches `b/parts` of the total
/// `d(d+1)/2`. `tri_cut(d, p, 0) == 0` and `tri_cut(d, p, p) == d`.
fn tri_cut(d: usize, parts: usize, b: usize) -> usize {
    if b >= parts {
        return d;
    }
    let total = d * (d + 1) / 2;
    let target = total * b / parts;
    let (mut lo, mut hi) = (0usize, d);
    while lo < hi {
        let mid = (lo + hi) / 2;
        let cum = mid * d - mid * mid.saturating_sub(1) / 2;
        if cum >= target {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    lo
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn rand_t(rng: &mut Pcg64, shape: &[usize]) -> Tensor {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(&mut t.data, 1.0);
        t
    }

    #[test]
    fn pool_scatter_matches_exec_scatter_in_slot_order() {
        let task = |_w: &mut usize, slot: usize| Ok(slot * slot + 1);
        let mut one = vec![0usize];
        let want = exec::scatter(&mut one, 9, task).unwrap();
        for shards in 1..=5 {
            let pool = WorkerPool::new(shards);
            let mut workers: Vec<usize> = (0..shards).collect();
            let got = pool.scatter(&mut workers, 9, task).unwrap();
            assert_eq!(got, want, "{shards} shards");
            // Reuse: a second dispatch through the parked workers agrees.
            let again = pool.scatter(&mut workers, 9, task).unwrap();
            assert_eq!(again, want, "{shards} shards, reused");
        }
    }

    #[test]
    fn workers_see_only_their_slots() {
        let pool = WorkerPool::new(3);
        let mut workers: Vec<Vec<usize>> = vec![Vec::new(), Vec::new(), Vec::new()];
        pool.scatter(&mut workers, 8, |w, slot| {
            w.push(slot);
            Ok(())
        })
        .unwrap();
        assert_eq!(workers[0], vec![0, 3, 6]);
        assert_eq!(workers[1], vec![1, 4, 7]);
        assert_eq!(workers[2], vec![2, 5]);
    }

    #[test]
    fn zero_slots_and_excess_workers() {
        let pool = WorkerPool::new(4);
        let mut workers = vec![(), (), (), ()];
        let out: Vec<usize> = pool.scatter(&mut workers, 0, |_, s| Ok(s)).unwrap();
        assert!(out.is_empty());
        // More workers than slots: only `slots` workers are dispatched.
        let out = pool.scatter(&mut workers, 2, |_, s| Ok(s + 10)).unwrap();
        assert_eq!(out, vec![10, 11]);
    }

    #[test]
    fn task_errors_propagate_and_pool_survives() {
        let pool = WorkerPool::new(2);
        let mut workers = vec![(), ()];
        let err = pool
            .scatter(&mut workers, 4, |_, slot| {
                if slot == 2 {
                    anyhow::bail!("boom at slot {slot}")
                }
                Ok(slot)
            })
            .unwrap_err();
        assert!(format!("{err}").contains("boom"), "{err}");
        // Failed slots must not leak initialized non-failed outputs, and
        // the pool must keep working.
        let ok = pool.scatter(&mut workers, 4, |_, s| Ok(s)).unwrap();
        assert_eq!(ok, vec![0, 1, 2, 3]);
    }

    #[test]
    fn lowest_indexed_workers_error_wins() {
        let pool = WorkerPool::new(3);
        let mut workers = vec![(), (), ()];
        // Slots 1 (worker 1) and 2 (worker 2) both fail; worker 1 wins.
        let err = pool
            .scatter(&mut workers, 3, |_, slot| {
                if slot >= 1 {
                    anyhow::bail!("fail {slot}")
                }
                Ok(slot)
            })
            .unwrap_err();
        assert_eq!(format!("{err}"), "fail 1");
    }

    #[test]
    fn panics_resurface_and_pool_is_reusable_after() {
        let pool = WorkerPool::new(2);
        let caught = catch_unwind(AssertUnwindSafe(|| {
            let mut workers = vec![(), ()];
            let _ = pool.scatter(&mut workers, 4, |_, slot| {
                if slot == 3 {
                    panic!("worker panic at slot {slot}");
                }
                Ok(slot)
            });
        }));
        assert!(caught.is_err(), "panic must propagate to the dispatcher");
        let mut workers = vec![(), ()];
        let ok = pool.scatter(&mut workers, 4, |_, s| Ok(s * 2)).unwrap();
        assert_eq!(ok, vec![0, 2, 4, 6]);
    }

    #[test]
    fn dropped_results_do_not_leak_or_double_free() {
        // Heap-owning T over both success and failure paths (miri-style
        // smoke for the MaybeUninit slab; under a leak-checking allocator
        // this would flag either bug).
        let pool = WorkerPool::new(3);
        let mut workers = vec![(), (), ()];
        let got: Vec<String> = pool
            .scatter(&mut workers, 7, |_, s| Ok(format!("slot-{s}")))
            .unwrap();
        assert_eq!(got[6], "slot-6");
        let err = pool
            .scatter(&mut workers, 7, |_, s| {
                if s == 4 {
                    anyhow::bail!("no slot 4")
                }
                Ok(format!("slot-{s}"))
            })
            .unwrap_err();
        assert!(format!("{err}").contains("no slot 4"));
    }

    #[test]
    fn tri_cut_partitions_the_row_range() {
        for d in [1usize, 2, 3, 7, 48, 129] {
            for parts in [1usize, 2, 3, 5, 8] {
                assert_eq!(tri_cut(d, parts, 0), 0);
                assert_eq!(tri_cut(d, parts, parts), d);
                for b in 0..parts {
                    assert!(tri_cut(d, parts, b) <= tri_cut(d, parts, b + 1));
                }
            }
        }
    }

    #[test]
    fn banded_kernels_are_bitwise_identical_to_serial() {
        // The load-bearing ADR-007 property: intra-shard banding must not
        // perturb a single bit, for every backend, at shapes both above
        // and below the dispatch threshold (the banded path is called
        // directly to cover the latter).
        let mut rng = Pcg64::seeded(4007);
        for &(m, k, n) in &[(64usize, 96usize, 48usize), (13, 31, 7), (5, 17, 1)] {
            let a = rand_t(&mut rng, &[m, k]);
            let b = rand_t(&mut rng, &[k, n]);
            for be in Backend::all() {
                let mut ws = Workspace::new();
                let mut want = Tensor::zeros(&[m, n]);
                be.matmul_into_ws(&a, &b, &mut want, &mut ws);
                for width in [2usize, 3, 5] {
                    let pool = WorkerPool::new(width);
                    let mut got = Tensor::filled(&[m, n], f32::NAN);
                    pool.matmul_banded(be, &a, &b, &mut got);
                    assert_eq!(
                        got.data,
                        want.data,
                        "matmul {m}x{k}x{n} {} width {width}",
                        be.name()
                    );
                }
            }
        }
        for &(n, d) in &[(96usize, 48usize), (9, 33), (4, 3)] {
            let a = rand_t(&mut rng, &[n, d]);
            for be in Backend::all() {
                let mut ws = Workspace::new();
                let mut want = Tensor::zeros(&[d, d]);
                be.gram_t_into_ws(&a, &mut want, &mut ws);
                for width in [2usize, 3, 5] {
                    let pool = WorkerPool::new(width);
                    let mut got = Tensor::filled(&[d, d], f32::NAN);
                    pool.gram_t_banded(be, &a, &mut got);
                    assert_eq!(
                        got.data,
                        want.data,
                        "gram_t {n}x{d} {} width {width}",
                        be.name()
                    );
                }
            }
        }
    }

    #[test]
    fn threshold_path_delegates_serially_and_stays_identical() {
        let mut rng = Pcg64::seeded(4008);
        let a = rand_t(&mut rng, &[8, 8]);
        let b = rand_t(&mut rng, &[8, 8]);
        let be = Backend::micro();
        let pool = WorkerPool::new(4);
        let mut ws = Workspace::new();
        let mut want = Tensor::zeros(&[8, 8]);
        be.matmul_into_ws(&a, &b, &mut want, &mut ws);
        let mut got = Tensor::zeros(&[8, 8]);
        pool.matmul_into_ws(be, &a, &b, &mut got, &mut ws);
        assert_eq!(got.data, want.data);
        let mut gt_want = Tensor::zeros(&[8, 8]);
        be.gram_t_into_ws(&a, &mut gt_want, &mut ws);
        let mut gt_got = Tensor::zeros(&[8, 8]);
        pool.gram_t_into_ws(be, &a, &mut gt_got, &mut ws);
        assert_eq!(gt_got.data, gt_want.data);
    }
}
