//! Sharded scatter executor (DESIGN.md ADR-004): run `slots` independent
//! micro-tasks over a set of worker states on scoped threads, and hand the
//! results back **in slot order** no matter which worker finished first.
//!
//! The executor deliberately knows nothing about gradients: a task is any
//! `Fn(&mut W, slot) -> Result<T>`. The trainer drives it with micro-batch
//! gradient tasks and refit chunk-collection tasks; the bench harness
//! drives it with synthetic matmul tasks; the proptests drive it with
//! arithmetic leaves. Determinism comes from the contract, not the caller:
//!
//! - slot assignment is a pure function of `(slot, worker_count)`
//!   (round-robin, [`worker_of_slot`]), so the same worker state sees the
//!   same slots every run;
//! - results land in a slot-indexed array, so downstream reductions
//!   (`coordinator::reduce`) see leaves in canonical order regardless of
//!   thread scheduling;
//! - with one worker (or one slot) no thread is spawned at all — the
//!   serial path and the sharded path are the same code.
//!
//! Workers own their mutable state (`Workspace` arena, `FitBuffer`
//! segment, data view, gather scratch), which is what makes the scatter
//! data-race-free by construction: a worker's `&mut W` moves into exactly
//! one scope thread.

/// Worker index that owns `slot` among `workers` workers (round-robin).
/// Pure and total: the proptests check the induced position ranges
/// partition the stream.
#[inline]
pub fn worker_of_slot(slot: usize, workers: usize) -> usize {
    debug_assert!(workers > 0);
    slot % workers
}

/// How many threads a scatter over `slots` slots with `shards` configured
/// shards actually uses (no point spawning idle workers).
#[inline]
pub fn effective_workers(shards: usize, slots: usize) -> usize {
    shards.max(1).min(slots.max(1))
}

/// Scatter `slots` tasks across `workers`, gather results in slot order.
///
/// Each worker `w` processes its slots `{s : s % n == w}` in increasing
/// order on its own scoped thread (`n = min(workers.len(), slots)`,
/// capped so no thread starts with nothing to do). On failure the error
/// of the lowest-indexed failing worker is returned (a deterministic
/// choice — errors must not race either); worker panics propagate.
pub fn scatter<W, T, F>(workers: &mut [W], slots: usize, task: F) -> anyhow::Result<Vec<T>>
where
    W: Send,
    T: Send,
    F: Fn(&mut W, usize) -> anyhow::Result<T> + Sync,
{
    assert!(!workers.is_empty(), "scatter needs at least one worker");
    if slots == 0 {
        return Ok(Vec::new());
    }
    // Single source of truth with the refit gather's segment index math,
    // which reads chunk c from workers[c % n].fit_seg.
    let n = effective_workers(workers.len(), slots);
    if n == 1 {
        // Serial fast path: same slot order, no thread overhead.
        let w = &mut workers[0];
        let mut out = Vec::with_capacity(slots);
        for slot in 0..slots {
            out.push(task(&mut *w, slot)?);
        }
        return Ok(out);
    }

    let task = &task;
    let results: Vec<anyhow::Result<Vec<(usize, T)>>> = std::thread::scope(|scope| {
        let handles: Vec<_> = workers[..n]
            .iter_mut()
            .enumerate()
            .map(|(w, worker)| {
                scope.spawn(move || -> anyhow::Result<Vec<(usize, T)>> {
                    let mut mine = Vec::new();
                    let mut slot = w;
                    while slot < slots {
                        mine.push((slot, task(&mut *worker, slot)?));
                        slot += n;
                    }
                    Ok(mine)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| match h.join() {
                Ok(r) => r,
                Err(panic) => std::panic::resume_unwind(panic),
            })
            .collect()
    });

    // Gather into slot order; keep the lowest-indexed worker's error.
    let mut out: Vec<Option<T>> = (0..slots).map(|_| None).collect();
    let mut first_err: Option<anyhow::Error> = None;
    for r in results {
        match r {
            Ok(pairs) => {
                for (slot, v) in pairs {
                    out[slot] = Some(v);
                }
            }
            Err(e) => {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
        }
    }
    if let Some(e) = first_err {
        return Err(e);
    }
    Ok(out
        .into_iter()
        .map(|o| o.expect("every slot filled by its round-robin owner"))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slot_assignment_round_robin() {
        assert_eq!(worker_of_slot(0, 3), 0);
        assert_eq!(worker_of_slot(4, 3), 1);
        assert_eq!(worker_of_slot(5, 3), 2);
        assert_eq!(effective_workers(4, 2), 2);
        assert_eq!(effective_workers(0, 8), 1);
        assert_eq!(effective_workers(2, 8), 2);
    }

    #[test]
    fn serial_and_threaded_scatter_agree_in_slot_order() {
        // Worker state is its index; the task value depends only on the
        // slot, so any worker count must produce the identical vector.
        let task = |_w: &mut usize, slot: usize| Ok(slot * slot + 1);
        let mut one = vec![0usize];
        let want = scatter(&mut one, 9, task).unwrap();
        for shards in 2..=5 {
            let mut workers: Vec<usize> = (0..shards).collect();
            let got = scatter(&mut workers, 9, task).unwrap();
            assert_eq!(got, want, "{shards} shards");
        }
    }

    #[test]
    fn workers_see_only_their_slots() {
        let mut workers: Vec<Vec<usize>> = vec![Vec::new(), Vec::new(), Vec::new()];
        scatter(&mut workers, 8, |w, slot| {
            w.push(slot);
            Ok(())
        })
        .unwrap();
        assert_eq!(workers[0], vec![0, 3, 6]);
        assert_eq!(workers[1], vec![1, 4, 7]);
        assert_eq!(workers[2], vec![2, 5]);
    }

    #[test]
    fn zero_slots_and_excess_workers() {
        let mut workers = vec![(), (), (), ()];
        let out: Vec<usize> = scatter(&mut workers, 0, |_, s| Ok(s)).unwrap();
        assert!(out.is_empty());
        // more workers than slots: only `slots` threads do work
        let out = scatter(&mut workers, 2, |_, s| Ok(s + 10)).unwrap();
        assert_eq!(out, vec![10, 11]);
    }

    #[test]
    fn task_errors_propagate() {
        let mut workers = vec![(), ()];
        let err = scatter(&mut workers, 4, |_, slot| {
            if slot == 2 {
                anyhow::bail!("boom at slot {slot}")
            }
            Ok(slot)
        })
        .unwrap_err();
        assert!(format!("{err}").contains("boom"), "{err}");
    }
}
