//! Deterministic fixed-topology gradient reduction (DESIGN.md ADR-004).
//!
//! Floating-point addition is not associative, so a reduction whose shape
//! depends on how many workers happened to finish first would make
//! `--shards N` runs drift from serial runs in the low bits — and every
//! downstream optimizer step amplifies the drift. The executor therefore
//! separates *where a leaf is computed* from *how leaves are combined*:
//! workers fill a slot-indexed leaf array (micro-batch slot = leaf index),
//! and the combine walks a reduction tree whose topology is a function of
//! the leaf count **only**. The topology chosen is the left-deep tree over
//! slot order — the same shape as a serial accumulation fold — so
//! `shards=N` is bit-identical to `shards=1` by construction. (A balanced
//! binary tree would also be shard-count invariant, but would change the
//! serial baseline's bits for zero accuracy gain at `accum`-sized leaf
//! counts.) Note the equivalence is within the ADR-004 trainer: the
//! positional data pipeline derives its epoch permutations differently
//! from the pre-ADR-004 stateful shuffle, so same-seed runs across that
//! boundary draw examples in a different order.
//!
//! The proptests (`rust/tests/proptests.rs`) pin the contract: the
//! reduction equals the serial left fold exactly (bitwise) for arbitrary
//! shard counts and gradient lengths. The scalar traces (loss, accuracy,
//! cost units) are folded by the coordinator in the same fixed slot
//! order.

use crate::model::params::FlatGrad;

/// Reduce slot-ordered gradient leaves into leaf 0 (left-deep topology).
/// Returns `None` for an empty leaf list. Consumes the vector so leaf 0's
/// slabs are reused as the accumulator — no allocation.
pub fn tree_reduce_grads(leaves: Vec<FlatGrad>) -> Option<FlatGrad> {
    let mut it = leaves.into_iter();
    let mut acc = it.next()?;
    for leaf in it {
        acc.axpy(1.0, &leaf);
    }
    Some(acc)
}

/// Reduce slot-ordered raw slices into `out` (same topology as
/// [`tree_reduce_grads`], exposed for the proptests and the bench
/// harness, which carry plain buffers instead of `FlatGrad`s).
pub fn tree_reduce_into(out: &mut [f32], leaves: &[&[f32]]) {
    out.fill(0.0);
    for leaf in leaves {
        debug_assert_eq!(leaf.len(), out.len(), "leaf length mismatch");
        for (o, v) in out.iter_mut().zip(*leaf) {
            *o += v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn leaf(rng: &mut Pcg64, n: usize) -> FlatGrad {
        let mut g = FlatGrad {
            trunk: vec![0.0; n],
            head_w: vec![0.0; 3],
            head_b: vec![0.0; 2],
        };
        rng.fill_normal(&mut g.trunk, 1.0);
        rng.fill_normal(&mut g.head_w, 1.0);
        rng.fill_normal(&mut g.head_b, 1.0);
        g
    }

    #[test]
    fn reduce_matches_manual_left_fold_bitwise() {
        let mut rng = Pcg64::seeded(11);
        let leaves: Vec<FlatGrad> = (0..7).map(|_| leaf(&mut rng, 33)).collect();
        let mut want = leaves[0].clone();
        for l in &leaves[1..] {
            want.axpy(1.0, l);
        }
        let got = tree_reduce_grads(leaves).unwrap();
        assert_eq!(got.trunk, want.trunk);
        assert_eq!(got.head_w, want.head_w);
        assert_eq!(got.head_b, want.head_b);
    }

    #[test]
    fn empty_and_singleton_leaves() {
        assert!(tree_reduce_grads(Vec::new()).is_none());
        let mut rng = Pcg64::seeded(12);
        let l = leaf(&mut rng, 5);
        let got = tree_reduce_grads(vec![l.clone()]).unwrap();
        assert_eq!(got.trunk, l.trunk);
    }

    #[test]
    fn slice_reduce_overwrites_dirty_output() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [0.5f32, -2.0, 1.0];
        let mut out = [f32::NAN; 3];
        tree_reduce_into(&mut out, &[&a, &b]);
        assert_eq!(out, [1.5, 0.0, 4.0]);
        tree_reduce_into(&mut out, &[]);
        assert_eq!(out, [0.0, 0.0, 0.0]);
    }

}
