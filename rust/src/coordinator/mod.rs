//! Layer-3 coordinator: the paper's training system.
//!
//! `Trainer` drives both Algorithm 1 (predicted gradient descent, "GPR")
//! and Algorithm 2 (vanilla) over the same runtime, data pipeline and
//! optimizer so wall-clock comparisons are apples-to-apples (Figure 1).
//!
//! One GPR micro-batch (DESIGN.md §6):
//!   control:    train_grads  -> g_ct, a_c, p_c     (Forward + Backward)
//!               predict_grad -> g_cp               (predictor on control)
//!   prediction: cheap_fwd    -> a_p, p_p           (CheapForward)
//!               predict_grad -> g_p
//!   combine:    g = f·g_ct + (1−f)(g_p − (g_cp − g_ct))     (eq. 1)
//!
//! Micro-batches accumulate (paper: 8 per update) before one optimizer
//! step; the predictor refits every `refit_every` updates from
//! per-example gradients.
//!
//! Sharding (ADR-004): the micro-batches of one update are independent
//! estimators (eq. 1 combines per micro-batch), so the update is a
//! scatter/reduce: `--shards N` worker threads each own a [`ShardWorker`]
//! (data view, `Workspace` arena, `FitBuffer` refit segment, gather
//! scratch) and compute their round-robin share of the micro-batch slots
//! against the shared `Runtime`; the coordinator reduces the slot-ordered
//! gradients through the fixed-topology tree (`reduce`) and steps the
//! optimizer serially. `shards=N` is bit-identical to `shards=1` — the
//! determinism test (`rust/tests/shard_determinism.rs`) pins it.

pub mod adaptive;
pub mod combine;
pub mod exec;
pub mod reduce;

use crate::config::{Algo, RunConfig};
use crate::data::loader::{DataPipeline, ShardDataView};
use crate::metrics::{accuracy, alignment_of, AlignmentMeter, Ema, LogRow};
use crate::model::params::{FlatGrad, ParamStore};
use crate::optim::{OptimConfig, Optimizer};
use crate::predictor::fit::{fit_with_ws, FitBuffer};
use crate::predictor::{residuals, Predictor};
use crate::runtime::{DeviceParams, DevicePredictor, Runtime, TrainOut};
use crate::tensor::{backend, Backend, Tensor, Workspace};
use crate::util::{CsvWriter, Stopwatch};

/// Where the control-variate combine runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CombinePath {
    /// Host loop (default — avoids 4 device round-trips; see §Perf).
    Host,
    /// The `cv_combine` pallas artifact (exercises the full L1 path).
    Device,
}

/// Everything one worker thread owns (ADR-004). Nothing here is shared:
/// the scatter hands each worker's `&mut ShardWorker` to exactly one
/// scoped thread, which is what makes the update data-race-free without
/// locks on the hot path.
pub struct ShardWorker {
    /// Position-addressed window onto the training stream (shared
    /// `Arc<Dataset>`, private per-epoch permutation cache).
    view: ShardDataView,
    /// This worker's refit ring segment: its round-robin share of the
    /// per-example gradient chunks lands here, then the coordinator
    /// gathers segments in canonical chunk order.
    fit_seg: FitBuffer,
    /// Private scratch arena — per-worker reuse keeps the steady state
    /// allocation-free with no cross-thread churn (the `alloc-counter`
    /// test asserts this per thread).
    ws: Workspace,
    /// Gather scratch for the control batch (capacity retained).
    x: Vec<f32>,
    y: Vec<i32>,
    /// Gather scratch for the prediction batch.
    xp: Vec<f32>,
    yp: Vec<i32>,
}

/// Per-update constants a micro-batch slot task needs — snapshotted by
/// the coordinator before the scatter so worker threads share only
/// immutable state.
struct MicroCtx<'a> {
    rt: &'a Runtime,
    dev: &'a DeviceParams,
    dev_pred: Option<&'a DevicePredictor>,
    algo: Algo,
    /// Full micro-batch size m, control/prediction split (mc, mp).
    m: usize,
    mc: usize,
    mp: usize,
    /// Effective control fraction mc/m (quantization-corrected).
    f_eff: f32,
    /// Whether the predictor participates this update (fitted and mp > 0)
    /// — decided once per update, so every shard agrees.
    use_pred: bool,
    combine: CombinePath,
    classes: usize,
}

impl MicroCtx<'_> {
    /// Stream positions one micro-batch slot consumes. The prediction
    /// batch is only drawn when the predictor runs — same consumption
    /// rule on every shard count, so slot offsets are deterministic.
    fn consumed_per_slot(&self) -> usize {
        match self.algo {
            Algo::Baseline => self.m,
            Algo::Gpr => self.mc + if self.use_pred { self.mp } else { 0 },
        }
    }
}

/// One micro-batch slot's contribution: the gradient leaf plus the scalar
/// traces, reduced by the coordinator in slot order.
struct MicroOut {
    grad: FlatGrad,
    loss: f32,
    acc: f64,
    cost: f64,
    examples: usize,
}

/// One micro-batch slot (either algorithm) at stream position `pos`,
/// running entirely on the calling worker thread.
fn run_micro(ctx: &MicroCtx, w: &mut ShardWorker, pos: usize) -> anyhow::Result<MicroOut> {
    let cost = crate::theory::CostModel::default();
    match ctx.algo {
        // Algorithm 2 micro-batch: full Forward+Backward on all m examples.
        Algo::Baseline => {
            w.view.batch_at(pos, ctx.m, &mut w.x, &mut w.y);
            let out = ctx.rt.train_grads(ctx.dev, &w.x, &w.y, ctx.m)?;
            let acc = accuracy(&out.probs, &w.y, ctx.classes);
            let TrainOut { loss, g_trunk, g_head_w, g_head_b, .. } = out;
            Ok(MicroOut {
                grad: FlatGrad { trunk: g_trunk, head_w: g_head_w, head_b: g_head_b },
                loss,
                acc,
                cost: cost.cost_vanilla(ctx.m as f64),
                examples: ctx.m,
            })
        }
        // Algorithm 1 micro-batch: control + prediction and the
        // control-variate combine.
        Algo::Gpr => {
            // -- control micro-batch: true gradient + activations --------
            w.view.batch_at(pos, ctx.mc, &mut w.x, &mut w.y);
            let ctrl = ctx.rt.train_grads(ctx.dev, &w.x, &w.y, ctx.mc)?;
            let acc = accuracy(&ctrl.probs, &w.y, ctx.classes);
            let mut g = FlatGrad {
                trunk: ctrl.g_trunk,
                head_w: ctrl.g_head_w,
                head_b: ctrl.g_head_b,
            };
            let c_units =
                cost.cost_vanilla(ctx.mc as f64) + cost.cheap_forward * ctx.mp as f64;
            let examples = ctx.mc + ctx.mp;

            // Until the first fit the predictor is identically zero;
            // eq. (1) then reduces to g_ct (still unbiased). Skip the
            // device calls — and the prediction draw (consumed_per_slot
            // matches).
            if !ctx.use_pred {
                return Ok(MicroOut { grad: g, loss: ctrl.loss, acc, cost: c_units, examples });
            }
            let dev_pred = ctx
                .dev_pred
                .expect("coordinator uploads the predictor before a use_pred scatter");

            // -- predictor on the control micro-batch (g_cp) --------------
            let pc =
                ctx.rt.predict_grad(&ctrl.a, &ctrl.probs, &w.y, ctx.dev, dev_pred, ctx.mc)?;

            // -- prediction micro-batch: CheapForward + predictor (g_p) ---
            w.view.batch_at(pos + ctx.mc, ctx.mp, &mut w.xp, &mut w.yp);
            let (a_p, probs_p) = ctx.rt.cheap_fwd(ctx.dev, &w.xp, ctx.mp)?;
            let pp = ctx.rt.predict_grad(&a_p, &probs_p, &w.yp, ctx.dev, dev_pred, ctx.mp)?;

            let g_cp = FlatGrad { trunk: pc.g_trunk, head_w: pc.g_head_w, head_b: pc.g_head_b };
            let g_p = FlatGrad { trunk: pp.g_trunk, head_w: pp.g_head_w, head_b: pp.g_head_b };

            match ctx.combine {
                CombinePath::Host => {
                    // eq. (1) fused in place over the control-gradient
                    // buffers: one pass, no fresh allocation (ADR-003).
                    combine::cv_combine_into(&mut g, &g_cp, &g_p, ctx.f_eff);
                }
                CombinePath::Device => {
                    let v = ctx.rt.cv_combine(
                        &g.concat(),
                        &g_cp.concat(),
                        &g_p.concat(),
                        ctx.f_eff,
                    )?;
                    g = FlatGrad::from_concat(&v, g.trunk.len(), g.head_w.len());
                }
            }
            Ok(MicroOut { grad: g, loss: ctrl.loss, acc, cost: c_units, examples })
        }
    }
}

pub struct Trainer {
    pub cfg: RunConfig,
    pub rt: Runtime,
    pub params: ParamStore,
    pub opt: Optimizer,
    pub pred: Predictor,
    fit_buf: FitBuffer,
    pub data: DataPipeline,
    pub tracker: AlignmentMeter,
    /// Host tensor backend selected at startup from `cfg.backend` (Auto →
    /// calibration probe); threaded through the fit and the optimizer.
    pub backend: Backend,
    /// Long-lived scratch arena threaded through the predictor refit so
    /// repeat fits reuse the same slabs (ADR-003).
    ws: Workspace,
    /// One state bundle per configured shard (ADR-004); `workers[0]` is
    /// the serial path's state when `shards = 1`.
    workers: Vec<ShardWorker>,
    dev_pred: Option<DevicePredictor>,
    /// Theorem-4 online controller (enabled by cfg.adaptive_f).
    pub adaptive: Option<adaptive::AdaptiveF>,
    pub combine_path: CombinePath,
    pub log: Vec<LogRow>,
    /// Analytic compute units consumed (paper cost model), for the
    /// cost-model bench.
    pub cost_units: f64,
    pub examples_seen: usize,
    step: usize,
}

impl Trainer {
    pub fn new(cfg: RunConfig) -> anyhow::Result<Trainer> {
        cfg.validate()?;
        // Install the tensor backend first: every dense host path below
        // (fit, Muon, diagnostics) dispatches through it.
        let be = backend::set_active(cfg.backend);
        crate::log_info!("tensor backend: {} (requested: {})", be.name(), cfg.backend.as_str());
        let rt = Runtime::load(&cfg.artifacts_dir)?;
        let params = ParamStore::load_init(&rt.manifest)?;
        let opt = Optimizer::new(
            cfg.optimizer,
            OptimConfig {
                lr: cfg.lr as f32,
                weight_decay: cfg.weight_decay as f32,
                backend: be,
                ..OptimConfig::default()
            },
            &params,
            &rt.manifest,
        );
        let pred = Predictor::new(rt.manifest.trunk_params, rt.manifest.width, rt.manifest.rank);
        let fit_buf = FitBuffer::new(rt.manifest.n_fit);
        let data = DataPipeline::build(
            cfg.train_size,
            cfg.val_size,
            rt.manifest.image,
            rt.manifest.classes,
            cfg.aug_multiplier,
            cfg.seed,
        );
        let shards = cfg.shards.max(1);
        if shards > 1 {
            crate::log_info!("sharded executor: {shards} worker threads (ADR-004)");
        }
        let chunks = rt.manifest.n_fit.div_ceil(rt.manifest.n_chunk);
        // Each worker's segment holds exactly its worst-case round-robin
        // share of refit chunks — never more, so the ring cannot slide.
        let seg_cap = chunks.div_ceil(shards) * rt.manifest.n_chunk;
        let workers = (0..shards)
            .map(|_| ShardWorker {
                view: data.make_view(),
                fit_seg: FitBuffer::new(seg_cap.max(1)),
                ws: Workspace::new(),
                x: Vec::new(),
                y: Vec::new(),
                xp: Vec::new(),
                yp: Vec::new(),
            })
            .collect();
        let adaptive = cfg.adaptive_f.then(|| {
            adaptive::AdaptiveF::new(rt.manifest.fs.clone(), cfg.f)
        });
        Ok(Trainer {
            tracker: AlignmentMeter::default(),
            backend: be,
            ws: Workspace::new(),
            workers,
            fit_buf,
            adaptive,
            cfg,
            rt,
            params,
            opt,
            pred,
            data,
            dev_pred: None,
            combine_path: CombinePath::Host,
            log: Vec::new(),
            cost_units: 0.0,
            examples_seen: 0,
            step: 0,
        })
    }

    /// Pre-compile the artifacts this configuration will touch.
    pub fn warmup(&self) -> anyhow::Result<()> {
        let m = &self.rt.manifest;
        let mut names = vec![m.per_example_grads_name(), "cv_combine".to_string()];
        match self.cfg.algo {
            Algo::Baseline => names.push(m.train_grads_name(m.micro_batch)),
            Algo::Gpr => {
                // adaptive-f may visit every lowered fraction
                let fracs: Vec<f64> = if self.adaptive.is_some() {
                    m.fs.clone()
                } else {
                    vec![self.cfg.f]
                };
                for f in fracs {
                    let (mc, mp) = m.split_sizes(f);
                    names.push(m.train_grads_name(mc));
                    // predict artifacts are only touched when there is a
                    // prediction micro-batch (f < 1)
                    if mp > 0 {
                        names.push(m.predict_grad_name(mc));
                        names.push(m.cheap_fwd_name(mp));
                        names.push(m.predict_grad_name(mp));
                    }
                }
            }
        }
        names.push(m.cheap_fwd_name(m.val_batch));
        self.rt.warmup(&names)
    }

    pub fn step_count(&self) -> usize {
        self.step
    }

    /// Configured shard count (worker thread pool width).
    pub fn shards(&self) -> usize {
        self.workers.len()
    }

    // ---- one optimizer update (scatter/reduce over the shards) -----------

    /// Accumulate `cfg.accum` micro-batch gradients across the shard
    /// workers and return the reduced leaf sums in slot order — gradient
    /// plus the (loss, acc, cost, examples) traces.
    fn execute_update(
        &mut self,
        dev: &DeviceParams,
    ) -> anyhow::Result<(FlatGrad, f64, f64)> {
        let (mc, mp) = self.rt.manifest.split_sizes(self.cfg.f);
        let m = self.rt.manifest.micro_batch;
        let classes = self.rt.manifest.classes;
        let use_pred = self.cfg.algo == Algo::Gpr && self.pred.fits > 0 && mp > 0;
        if use_pred {
            // Upload once per update (version-cached) and share read-only
            // across the shards.
            let up = self.rt.upload_predictor(&self.pred, self.dev_pred.take())?;
            self.dev_pred = Some(up);
        }
        let ctx = MicroCtx {
            rt: &self.rt,
            dev,
            dev_pred: if use_pred { self.dev_pred.as_ref() } else { None },
            algo: self.cfg.algo,
            m,
            mc,
            mp,
            f_eff: mc as f32 / m as f32,
            use_pred,
            combine: self.combine_path,
            classes,
        };
        let per_slot = ctx.consumed_per_slot();
        let base = self.data.cursor();
        let slots = self.cfg.accum;
        // Scatter: each worker thread computes its round-robin slots
        // against disjoint stream ranges; gather is slot-ordered.
        let outs = exec::scatter(&mut self.workers, slots, |w, slot| {
            run_micro(&ctx, w, base + slot * per_slot)
        })?;
        self.data.advance(slots * per_slot);

        // Reduce: fixed topology over slot order (ADR-004) for the
        // gradient and every scalar trace.
        let mut loss_sum = 0.0f64;
        let mut acc_sum = 0.0f64;
        let mut cost_sum = 0.0f64;
        let mut examples = 0usize;
        let mut grads = Vec::with_capacity(outs.len());
        for o in outs {
            loss_sum += o.loss as f64;
            acc_sum += o.acc;
            cost_sum += o.cost;
            examples += o.examples;
            grads.push(o.grad);
        }
        let mut grad = reduce::tree_reduce_grads(grads)
            .expect("accum >= 1 is enforced by RunConfig::validate");
        grad.scale(1.0 / slots as f32);
        self.cost_units += cost_sum;
        self.examples_seen += examples;
        Ok((grad, loss_sum, acc_sum))
    }

    // ---- predictor refit -------------------------------------------------

    /// Collect per-example gradients (chunks scattered across the shards,
    /// gathered in canonical chunk order) and refit (U, B). Also feeds the
    /// Sec. 5.3 alignment tracker with (g_j, ĝ_j) pairs.
    pub fn refit_predictor(
        &mut self,
        dev: &crate::runtime::DeviceParams,
    ) -> anyhow::Result<Option<crate::predictor::fit::FitReport>> {
        let (n_chunk, chunks, d, classes, smoothing) = {
            let man = &self.rt.manifest;
            (
                man.n_chunk,
                man.n_fit.div_ceil(man.n_chunk),
                man.width,
                man.classes,
                man.label_smoothing as f32,
            )
        };
        for w in &mut self.workers {
            w.fit_seg.clear();
        }
        let base = self.data.cursor();
        let rt = &self.rt;
        let head_w = &self.params.head_w;
        exec::scatter(&mut self.workers, chunks, |w, slot| {
            w.view.batch_at(base + slot * n_chunk, n_chunk, &mut w.x, &mut w.y);
            let (g_rows, a, probs) = rt.per_example_grads(dev, &w.x, &w.y)?;
            let resid = residuals(&probs, &w.y, classes, smoothing);
            let mut h = w.ws.take_tensor(&[n_chunk, d]);
            Predictor::backprop_features_into(&resid, head_w, d, &mut h);
            for (j, g) in g_rows.iter().enumerate() {
                w.fit_seg.push(g, &a[j * d..(j + 1) * d], h.row(j));
            }
            w.ws.give_tensor(h);
            Ok(())
        })?;
        self.data.advance(chunks * n_chunk);
        // fitting also costs compute: fwd+bwd per example
        self.cost_units +=
            chunks as f64 * crate::theory::CostModel::default().cost_vanilla(n_chunk as f64);

        // Gather the worker segments into the fit ring in canonical chunk
        // order — bit-identical to a serial collection by construction.
        let nw = exec::effective_workers(self.workers.len(), chunks);
        self.fit_buf.clear();
        for c in 0..chunks {
            let seg = &self.workers[c % nw].fit_seg;
            let first = (c / nw) * n_chunk;
            for j in first..first + n_chunk {
                self.fit_buf.push(seg.grad(j), &seg.a1(j)[..d], seg.h(j));
            }
        }

        let report = fit_with_ws(
            self.backend,
            &mut self.pred,
            &self.fit_buf,
            self.cfg.ridge_lambda as f32,
            &mut self.ws,
        )?;
        crate::log_debug!(
            "refit: n={} energy={:.3} rel_err={:.3}",
            report.n,
            report.energy_captured,
            report.rel_error
        );
        // Alignment diagnostics with the *new* predictor on the same
        // samples (plug-in ρ̂/κ̂ of Sec. 5.3) — computed once per refit and
        // cached (a per-step recomputation over n_fit × P_T floats was the
        // top hot-path cost before the perf pass; see EXPERIMENTS.md §Perf).
        if self.cfg.track_alignment {
            let pairs: Vec<(Vec<f32>, Vec<f32>)> = (0..self.fit_buf.len())
                .map(|j| {
                    let a_row = &self.fit_buf.a1(j)[..d];
                    let h_row = self.fit_buf.h(j);
                    let pred_g = self.pred.predict_one_trunk(a_row, h_row);
                    (self.fit_buf.grad(j).to_vec(), pred_g)
                })
                .collect();
            self.tracker.update(alignment_of(&pairs));
        }
        Ok(Some(report))
    }

    // ---- evaluation --------------------------------------------------------

    /// Validation accuracy over all full val batches (CheapForward path).
    pub fn evaluate(&mut self, dev: &crate::runtime::DeviceParams) -> anyhow::Result<f64> {
        let man = &self.rt.manifest;
        let mut correct_weighted = 0.0;
        let mut batches = 0usize;
        for (x, y) in self.data.val_batches(man.val_batch) {
            let (_, probs) = self.rt.cheap_fwd(dev, &x, man.val_batch)?;
            correct_weighted += accuracy(&probs, &y, man.classes);
            batches += 1;
        }
        Ok(if batches == 0 { 0.0 } else { correct_weighted / batches as f64 })
    }

    // ---- the budgeted training loop ---------------------------------------

    /// Run until the wall-clock budget or step limit. Returns the log.
    /// `csv` optionally streams rows for the Figure 1 series.
    pub fn train(&mut self, mut csv: Option<&mut CsvWriter>) -> anyhow::Result<()> {
        self.warmup()?;
        let sw = Stopwatch::start();
        let mut loss_ema = Ema::new(0.2);
        loop {
            if self.cfg.budget_secs > 0.0 && sw.seconds() >= self.cfg.budget_secs {
                break;
            }
            if self.cfg.max_steps > 0 && self.step >= self.cfg.max_steps {
                break;
            }
            // Refit schedule: first GPR fit happens after the first
            // update (so early steps aren't all fit overhead), then every
            // refit_every updates.
            let dev = self.rt.upload_params(&self.params)?;
            // Refit only when a prediction micro-batch exists (f < 1);
            // at f = 1 Algorithm 1 degenerates to Algorithm 2 and the
            // predictor is never consulted.
            if self.cfg.algo == Algo::Gpr && self.rt.manifest.split_sizes(self.cfg.f).1 > 0 {
                let due = if self.pred.fits == 0 {
                    self.step >= 1
                } else {
                    self.cfg.refit_every > 0 && self.step % self.cfg.refit_every == 0
                };
                if due {
                    self.refit_predictor(&dev)?;
                    // Theorem 4 online: move f toward the quantized f*.
                    if let Some(ctl) = &mut self.adaptive {
                        let new_f = ctl.update(self.tracker.snapshot());
                        if (new_f - self.cfg.f).abs() > 1e-12 {
                            crate::log_info!(
                                "adaptive-f: {:.3} -> {new_f:.3} (switch #{})",
                                self.cfg.f,
                                ctl.switches
                            );
                            self.cfg.f = new_f;
                        }
                    }
                }
            }

            // Scatter micro-batches over the shards, reduce, step.
            let (grad, loss_sum, acc_sum) = self.execute_update(&dev)?;
            let manifest = self.rt.manifest.clone();
            self.opt.step(&mut self.params, &grad, &manifest);
            self.step += 1;

            let loss = loss_ema.push(loss_sum / self.cfg.accum as f64);
            let train_acc = acc_sum / self.cfg.accum as f64;

            // periodic eval + log
            let do_eval = self.cfg.eval_every > 0 && self.step % self.cfg.eval_every == 0;
            let val_acc = if do_eval {
                let dev2 = self.rt.upload_params(&self.params)?;
                self.evaluate(&dev2)?
            } else {
                f64::NAN
            };
            let align = self.tracker.snapshot();
            let row = LogRow {
                step: self.step,
                wall_secs: sw.seconds(),
                loss,
                train_acc,
                val_acc,
                rho: align.map_or(f64::NAN, |a| a.rho),
                kappa: align.map_or(f64::NAN, |a| a.kappa),
                phi: align.map_or(f64::NAN, |a| a.phi(self.cfg.f)),
                examples_seen: self.examples_seen,
            };
            if let Some(w) = csv.as_deref_mut() {
                w.row(&row.values())?;
            }
            if do_eval {
                crate::log_info!(
                    "step {:>5} t={:>7.1}s loss={:.4} train_acc={:.3} val_acc={:.3} rho={:.3}",
                    row.step,
                    row.wall_secs,
                    row.loss,
                    row.train_acc,
                    row.val_acc,
                    row.rho
                );
            }
            self.log.push(row);
        }
        // Final eval if the last step wasn't an eval step.
        if self
            .log
            .last()
            .map_or(true, |r| r.val_acc.is_nan())
        {
            let dev = self.rt.upload_params(&self.params)?;
            let val = self.evaluate(&dev)?;
            if let Some(r) = self.log.last_mut() {
                r.val_acc = val;
            }
        }
        Ok(())
    }

    /// Final validation accuracy from the log.
    pub fn final_val_acc(&self) -> f64 {
        self.log
            .iter()
            .rev()
            .find(|r| !r.val_acc.is_nan())
            .map_or(0.0, |r| r.val_acc)
    }

    /// Residual tensor helper exposed for diagnostics binaries.
    pub fn residual_tensor(&self, probs: &[f32], y: &[i32]) -> Tensor {
        residuals(
            probs,
            y,
            self.rt.manifest.classes,
            self.rt.manifest.label_smoothing as f32,
        )
    }
}
