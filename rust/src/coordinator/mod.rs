//! Layer-3 coordinator: the paper's training system.
//!
//! `Trainer` drives both Algorithm 1 (predicted gradient descent, "GPR")
//! and Algorithm 2 (vanilla) over the same runtime, data pipeline and
//! optimizer so wall-clock comparisons are apples-to-apples (Figure 1).
//!
//! One GPR micro-batch (DESIGN.md §6):
//!   control:    train_grads  -> g_ct, a_c, p_c     (Forward + Backward)
//!               predict_grad -> g_cp               (predictor on control)
//!   prediction: cheap_fwd    -> a_p, p_p           (CheapForward)
//!               predict_grad -> g_p
//!   combine:    g = f·g_ct + (1−f)(g_p − (g_cp − g_ct))     (eq. 1)
//!
//! Micro-batches accumulate (paper: 8 per update) before one optimizer
//! step; the predictor refits every `refit_every` updates from
//! per-example gradients.

pub mod adaptive;
pub mod combine;

use crate::config::{Algo, RunConfig};
use crate::data::loader::DataPipeline;
use crate::metrics::{accuracy, alignment_of, AlignmentMeter, Ema, LogRow};
use crate::model::params::{FlatGrad, ParamStore};
use crate::optim::{OptimConfig, Optimizer};
use crate::predictor::fit::{fit_with_ws, FitBuffer};
use crate::predictor::{residuals, Predictor};
use crate::runtime::{DevicePredictor, Runtime, TrainOut};
use crate::tensor::{backend, Backend, Tensor, Workspace};
use crate::util::{CsvWriter, Stopwatch};

/// Where the control-variate combine runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CombinePath {
    /// Host loop (default — avoids 4 device round-trips; see §Perf).
    Host,
    /// The `cv_combine` pallas artifact (exercises the full L1 path).
    Device,
}

pub struct Trainer {
    pub cfg: RunConfig,
    pub rt: Runtime,
    pub params: ParamStore,
    pub opt: Optimizer,
    pub pred: Predictor,
    fit_buf: FitBuffer,
    pub data: DataPipeline,
    pub tracker: AlignmentMeter,
    /// Host tensor backend selected at startup from `cfg.backend` (Auto →
    /// calibration probe); threaded through the fit and the optimizer.
    pub backend: Backend,
    /// Long-lived scratch arena threaded through the predictor refit so
    /// repeat fits reuse the same slabs (ADR-003).
    ws: Workspace,
    dev_pred: Option<DevicePredictor>,
    /// Theorem-4 online controller (enabled by cfg.adaptive_f).
    pub adaptive: Option<adaptive::AdaptiveF>,
    pub combine_path: CombinePath,
    pub log: Vec<LogRow>,
    /// Analytic compute units consumed (paper cost model), for the
    /// cost-model bench.
    pub cost_units: f64,
    pub examples_seen: usize,
    step: usize,
}

impl Trainer {
    pub fn new(cfg: RunConfig) -> anyhow::Result<Trainer> {
        cfg.validate()?;
        // Install the tensor backend first: every dense host path below
        // (fit, Muon, diagnostics) dispatches through it.
        let be = backend::set_active(cfg.backend);
        crate::log_info!("tensor backend: {} (requested: {})", be.name(), cfg.backend.as_str());
        let rt = Runtime::load(&cfg.artifacts_dir)?;
        let params = ParamStore::load_init(&rt.manifest)?;
        let opt = Optimizer::new(
            cfg.optimizer,
            OptimConfig {
                lr: cfg.lr as f32,
                weight_decay: cfg.weight_decay as f32,
                backend: be,
                ..OptimConfig::default()
            },
            &params,
            &rt.manifest,
        );
        let pred = Predictor::new(rt.manifest.trunk_params, rt.manifest.width, rt.manifest.rank);
        let fit_buf = FitBuffer::new(rt.manifest.n_fit);
        let data = DataPipeline::build(
            cfg.train_size,
            cfg.val_size,
            rt.manifest.image,
            rt.manifest.classes,
            cfg.aug_multiplier,
            cfg.seed,
        );
        let adaptive = cfg.adaptive_f.then(|| {
            adaptive::AdaptiveF::new(rt.manifest.fs.clone(), cfg.f)
        });
        Ok(Trainer {
            tracker: AlignmentMeter::default(),
            backend: be,
            ws: Workspace::new(),
            fit_buf,
            adaptive,
            cfg,
            rt,
            params,
            opt,
            pred,
            data,
            dev_pred: None,
            combine_path: CombinePath::Host,
            log: Vec::new(),
            cost_units: 0.0,
            examples_seen: 0,
            step: 0,
        })
    }

    /// Pre-compile the artifacts this configuration will touch.
    pub fn warmup(&self) -> anyhow::Result<()> {
        let m = &self.rt.manifest;
        let mut names = vec![m.per_example_grads_name(), "cv_combine".to_string()];
        match self.cfg.algo {
            Algo::Baseline => names.push(m.train_grads_name(m.micro_batch)),
            Algo::Gpr => {
                // adaptive-f may visit every lowered fraction
                let fracs: Vec<f64> = if self.adaptive.is_some() {
                    m.fs.clone()
                } else {
                    vec![self.cfg.f]
                };
                for f in fracs {
                    let (mc, mp) = m.split_sizes(f);
                    names.push(m.train_grads_name(mc));
                    // predict artifacts are only touched when there is a
                    // prediction micro-batch (f < 1)
                    if mp > 0 {
                        names.push(m.predict_grad_name(mc));
                        names.push(m.cheap_fwd_name(mp));
                        names.push(m.predict_grad_name(mp));
                    }
                }
            }
        }
        names.push(m.cheap_fwd_name(m.val_batch));
        self.rt.warmup(&names)
    }

    pub fn step_count(&self) -> usize {
        self.step
    }

    // ---- single micro-batch gradients -----------------------------------

    /// Algorithm 2 micro-batch: full Forward+Backward on all m examples.
    fn micro_baseline(
        &mut self,
        dev: &crate::runtime::DeviceParams,
    ) -> anyhow::Result<(FlatGrad, f32, f64)> {
        let m = self.rt.manifest.micro_batch;
        let (mut x, mut y) = (Vec::new(), Vec::new());
        self.data.next_batch(m, &mut x, &mut y);
        let out = self.rt.train_grads(dev, &x, &y, m)?;
        let acc = accuracy(&out.probs, &y, self.rt.manifest.classes);
        self.examples_seen += m;
        self.cost_units += crate::theory::CostModel::default().cost_vanilla(m as f64);
        let TrainOut { loss, g_trunk, g_head_w, g_head_b, .. } = out;
        Ok((FlatGrad { trunk: g_trunk, head_w: g_head_w, head_b: g_head_b }, loss, acc))
    }

    /// Algorithm 1 micro-batch: control + prediction micro-batches and the
    /// control-variate combine.
    fn micro_gpr(
        &mut self,
        dev: &crate::runtime::DeviceParams,
    ) -> anyhow::Result<(FlatGrad, f32, f64)> {
        let man = &self.rt.manifest;
        let classes = man.classes;
        let (mc, mp) = man.split_sizes(self.cfg.f);
        let f_eff = mc as f32 / man.micro_batch as f32;

        // -- control micro-batch: true gradient + activations ------------
        let (mut xc, mut yc) = (Vec::new(), Vec::new());
        self.data.next_batch(mc, &mut xc, &mut yc);
        let ctrl = self.rt.train_grads(dev, &xc, &yc, mc)?;
        let acc = accuracy(&ctrl.probs, &yc, classes);
        let g_ct = FlatGrad {
            trunk: ctrl.g_trunk,
            head_w: ctrl.g_head_w,
            head_b: ctrl.g_head_b,
        };

        let cost = crate::theory::CostModel::default();
        self.cost_units += cost.cost_vanilla(mc as f64); // fwd+bwd on control
        self.examples_seen += mc + mp;

        // Until the first fit the predictor is identically zero; eq. (1)
        // then reduces to g_ct (still unbiased). Skip the device calls.
        if self.pred.fits == 0 || mp == 0 {
            self.cost_units += cost.cheap_forward * mp as f64;
            return Ok((g_ct, ctrl.loss, acc));
        }

        let dev_pred = self
            .rt
            .upload_predictor(&self.pred, self.dev_pred.take())?;

        // -- predictor on the control micro-batch (g_cp) ------------------
        let pc = self.rt.predict_grad(&ctrl.a, &ctrl.probs, &yc, dev, &dev_pred, mc)?;

        // -- prediction micro-batch: CheapForward + predictor (g_p) -------
        let (mut xp, mut yp) = (Vec::new(), Vec::new());
        self.data.next_batch(mp, &mut xp, &mut yp);
        let (a_p, probs_p) = self.rt.cheap_fwd(dev, &xp, mp)?;
        let pp = self.rt.predict_grad(&a_p, &probs_p, &yp, dev, &dev_pred, mp)?;
        self.cost_units += cost.cheap_forward * mp as f64;

        self.dev_pred = Some(dev_pred);

        let g_cp = FlatGrad { trunk: pc.g_trunk, head_w: pc.g_head_w, head_b: pc.g_head_b };
        let g_p = FlatGrad { trunk: pp.g_trunk, head_w: pp.g_head_w, head_b: pp.g_head_b };

        let g = match self.combine_path {
            CombinePath::Host => {
                // eq. (1) fused in place over the control-gradient buffers:
                // one pass, no fresh allocation (ADR-003).
                let mut g = g_ct;
                combine::cv_combine_into(&mut g, &g_cp, &g_p, f_eff);
                g
            }
            CombinePath::Device => {
                let v = self.rt.cv_combine(
                    &g_ct.concat(),
                    &g_cp.concat(),
                    &g_p.concat(),
                    f_eff,
                )?;
                FlatGrad::from_concat(&v, g_ct.trunk.len(), g_ct.head_w.len())
            }
        };
        Ok((g, ctrl.loss, acc))
    }

    // ---- predictor refit -------------------------------------------------

    /// Collect per-example gradients and refit (U, B). Also feeds the
    /// Sec. 5.3 alignment tracker with (g_j, ĝ_j) pairs.
    pub fn refit_predictor(
        &mut self,
        dev: &crate::runtime::DeviceParams,
    ) -> anyhow::Result<Option<crate::predictor::fit::FitReport>> {
        let man = &self.rt.manifest;
        let n_chunk = man.n_chunk;
        let chunks = man.n_fit.div_ceil(n_chunk);
        let d = man.width;
        let smoothing = man.label_smoothing as f32;
        self.fit_buf.clear();
        for _ in 0..chunks {
            let (mut x, mut y) = (Vec::new(), Vec::new());
            self.data.next_batch(n_chunk, &mut x, &mut y);
            let (g_rows, a, probs) = self.rt.per_example_grads(dev, &x, &y)?;
            // fitting also costs compute: fwd+bwd per example
            self.cost_units +=
                crate::theory::CostModel::default().cost_vanilla(n_chunk as f64);
            let resid = residuals(&probs, &y, man.classes, smoothing);
            let h = Predictor::backprop_features(&resid, &self.params.head_w, d);
            for (j, g) in g_rows.iter().enumerate() {
                self.fit_buf.push(g, &a[j * d..(j + 1) * d], h.row(j));
            }
        }
        let report = fit_with_ws(
            self.backend,
            &mut self.pred,
            &self.fit_buf,
            self.cfg.ridge_lambda as f32,
            &mut self.ws,
        )?;
        crate::log_debug!(
            "refit: n={} energy={:.3} rel_err={:.3}",
            report.n,
            report.energy_captured,
            report.rel_error
        );
        // Alignment diagnostics with the *new* predictor on the same
        // samples (plug-in ρ̂/κ̂ of Sec. 5.3) — computed once per refit and
        // cached (a per-step recomputation over n_fit × P_T floats was the
        // top hot-path cost before the perf pass; see EXPERIMENTS.md §Perf).
        if self.cfg.track_alignment {
            let pairs: Vec<(Vec<f32>, Vec<f32>)> = (0..self.fit_buf.len())
                .map(|j| {
                    let a_row = &self.fit_buf.a1(j)[..d];
                    let h_row = self.fit_buf.h(j);
                    let pred_g = self.pred.predict_one_trunk(a_row, h_row);
                    (self.fit_buf.grad(j).to_vec(), pred_g)
                })
                .collect();
            self.tracker.update(alignment_of(&pairs));
        }
        Ok(Some(report))
    }

    // ---- evaluation --------------------------------------------------------

    /// Validation accuracy over all full val batches (CheapForward path).
    pub fn evaluate(&mut self, dev: &crate::runtime::DeviceParams) -> anyhow::Result<f64> {
        let man = &self.rt.manifest;
        let mut correct_weighted = 0.0;
        let mut batches = 0usize;
        for (x, y) in self.data.val_batches(man.val_batch) {
            let (_, probs) = self.rt.cheap_fwd(dev, &x, man.val_batch)?;
            correct_weighted += accuracy(&probs, &y, man.classes);
            batches += 1;
        }
        Ok(if batches == 0 { 0.0 } else { correct_weighted / batches as f64 })
    }

    // ---- the budgeted training loop ---------------------------------------

    /// Run until the wall-clock budget or step limit. Returns the log.
    /// `csv` optionally streams rows for the Figure 1 series.
    pub fn train(&mut self, mut csv: Option<&mut CsvWriter>) -> anyhow::Result<()> {
        self.warmup()?;
        let sw = Stopwatch::start();
        let mut loss_ema = Ema::new(0.2);
        loop {
            if self.cfg.budget_secs > 0.0 && sw.seconds() >= self.cfg.budget_secs {
                break;
            }
            if self.cfg.max_steps > 0 && self.step >= self.cfg.max_steps {
                break;
            }
            // Refit schedule: first GPR fit happens after the first
            // update (so early steps aren't all fit overhead), then every
            // refit_every updates.
            let dev = self.rt.upload_params(&self.params)?;
            // Refit only when a prediction micro-batch exists (f < 1);
            // at f = 1 Algorithm 1 degenerates to Algorithm 2 and the
            // predictor is never consulted.
            if self.cfg.algo == Algo::Gpr && self.rt.manifest.split_sizes(self.cfg.f).1 > 0 {
                let due = if self.pred.fits == 0 {
                    self.step >= 1
                } else {
                    self.cfg.refit_every > 0 && self.step % self.cfg.refit_every == 0
                };
                if due {
                    self.refit_predictor(&dev)?;
                    // Theorem 4 online: move f toward the quantized f*.
                    if let Some(ctl) = &mut self.adaptive {
                        let new_f = ctl.update(self.tracker.snapshot());
                        if (new_f - self.cfg.f).abs() > 1e-12 {
                            crate::log_info!(
                                "adaptive-f: {:.3} -> {new_f:.3} (switch #{})",
                                self.cfg.f,
                                ctl.switches
                            );
                            self.cfg.f = new_f;
                        }
                    }
                }
            }

            // Accumulate micro-batch gradients.
            let mut acc_grad: Option<FlatGrad> = None;
            let mut loss_sum = 0.0f64;
            let mut acc_sum = 0.0f64;
            for _ in 0..self.cfg.accum {
                let (g, loss, acc) = match self.cfg.algo {
                    Algo::Baseline => self.micro_baseline(&dev)?,
                    Algo::Gpr => self.micro_gpr(&dev)?,
                };
                loss_sum += loss as f64;
                acc_sum += acc;
                match &mut acc_grad {
                    None => acc_grad = Some(g),
                    Some(t) => t.axpy(1.0, &g),
                }
            }
            let mut grad = acc_grad.unwrap();
            grad.scale(1.0 / self.cfg.accum as f32);
            let manifest = self.rt.manifest.clone();
            self.opt.step(&mut self.params, &grad, &manifest);
            self.step += 1;

            let loss = loss_ema.push(loss_sum / self.cfg.accum as f64);
            let train_acc = acc_sum / self.cfg.accum as f64;

            // periodic eval + log
            let do_eval = self.cfg.eval_every > 0 && self.step % self.cfg.eval_every == 0;
            let val_acc = if do_eval {
                let dev2 = self.rt.upload_params(&self.params)?;
                self.evaluate(&dev2)?
            } else {
                f64::NAN
            };
            let align = self.tracker.snapshot();
            let row = LogRow {
                step: self.step,
                wall_secs: sw.seconds(),
                loss,
                train_acc,
                val_acc,
                rho: align.map_or(f64::NAN, |a| a.rho),
                kappa: align.map_or(f64::NAN, |a| a.kappa),
                phi: align.map_or(f64::NAN, |a| a.phi(self.cfg.f)),
                examples_seen: self.examples_seen,
            };
            if let Some(w) = csv.as_deref_mut() {
                w.row(&row.values())?;
            }
            if do_eval {
                crate::log_info!(
                    "step {:>5} t={:>7.1}s loss={:.4} train_acc={:.3} val_acc={:.3} rho={:.3}",
                    row.step,
                    row.wall_secs,
                    row.loss,
                    row.train_acc,
                    row.val_acc,
                    row.rho
                );
            }
            self.log.push(row);
        }
        // Final eval if the last step wasn't an eval step.
        if self
            .log
            .last()
            .map_or(true, |r| r.val_acc.is_nan())
        {
            let dev = self.rt.upload_params(&self.params)?;
            let val = self.evaluate(&dev)?;
            if let Some(r) = self.log.last_mut() {
                r.val_acc = val;
            }
        }
        Ok(())
    }

    /// Final validation accuracy from the log.
    pub fn final_val_acc(&self) -> f64 {
        self.log
            .iter()
            .rev()
            .find(|r| !r.val_acc.is_nan())
            .map_or(0.0, |r| r.val_acc)
    }

    /// Residual tensor helper exposed for diagnostics binaries.
    pub fn residual_tensor(&self, probs: &[f32], y: &[i32]) -> Tensor {
        residuals(
            probs,
            y,
            self.rt.manifest.classes,
            self.rt.manifest.label_smoothing as f32,
        )
    }
}
