//! Layer-3 execution substrate: the deterministic scatter/reduce
//! machinery the training session runs on (DESIGN.md ADR-004).
//!
//! - [`exec`] — the sharded scatter executor: `slots` independent
//!   micro-tasks over per-worker state on scoped threads, results handed
//!   back in slot order regardless of thread scheduling.
//! - [`pool`] — the persistent parked worker pool (ADR-007): same
//!   scatter contract as [`exec`] without the per-update thread spawn,
//!   plus banded intra-shard matmul/gram kernels. Sessions dispatch
//!   through the pool; [`exec`] remains as the one-shot reference
//!   implementation (and the bench's spawn-overhead comparison point).
//! - [`reduce`] — fixed-topology (left-deep, slot-order) gradient
//!   reduction, so `--shards N` is bit-identical to serial.
//!
//! The training loop that used to live here (the monolithic `Trainer`)
//! moved behind the library-first session API in ADR-005: configuration
//! is `crate::session::SessionBuilder`, the loop is
//! `crate::session::TrainSession`, the eq.-1 combine and the adaptive-f
//! controller belong to `crate::estimator`, and metrics sinks are
//! `crate::observer` implementations. This module deliberately knows
//! nothing about gradients' meaning — only how to scatter work and
//! reduce leaves deterministically.

pub mod exec;
pub mod pool;
pub mod reduce;
