//! The control-variate combine (paper eq. 1) and the micro-batch split —
//! the two pure functions at the heart of Algorithm 1, kept separate so
//! property tests can hammer them without a runtime.

use crate::model::params::FlatGrad;

/// eq. (1):  g = f·g_ct + (1−f)·(g_p − (g_cp − g_ct)).
///
/// Unbiased (Lemma 1): E[g_cp] = E[g_p] ⇒ E[g] = E[g_ct] = ∇F.
pub fn cv_combine(g_ct: &FlatGrad, g_cp: &FlatGrad, g_p: &FlatGrad, f: f32) -> FlatGrad {
    let mut out = g_ct.clone();
    let apply = |o: &mut [f32], ct: &[f32], cp: &[f32], p: &[f32]| {
        for i in 0..o.len() {
            let ct_i = ct[i];
            o[i] = f * ct_i + (1.0 - f) * (p[i] - (cp[i] - ct_i));
        }
    };
    apply(&mut out.trunk, &g_ct.trunk, &g_cp.trunk, &g_p.trunk);
    apply(&mut out.head_w, &g_ct.head_w, &g_cp.head_w, &g_p.head_w);
    apply(&mut out.head_b, &g_ct.head_b, &g_cp.head_b, &g_p.head_b);
    out
}

/// Split a micro-batch index list into (control, prediction) parts with
/// |control| = max(1, round(f·m)). The two parts partition the input —
/// checked by the proptests.
pub fn split_indices(idx: &[usize], f: f64) -> (Vec<usize>, Vec<usize>) {
    let m = idx.len();
    let mc = ((f * m as f64).round() as usize).clamp(1, m);
    (idx[..mc].to_vec(), idx[mc..].to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fg(v: &[f32]) -> FlatGrad {
        FlatGrad { trunk: v.to_vec(), head_w: vec![v[0]; 2], head_b: vec![v[0]] }
    }

    #[test]
    fn f_one_recovers_true_gradient() {
        let g = cv_combine(&fg(&[1.0, 2.0]), &fg(&[9.0, 9.0]), &fg(&[5.0, 5.0]), 1.0);
        assert_eq!(g.trunk, vec![1.0, 2.0]);
    }

    #[test]
    fn perfect_predictor_blends_plainly() {
        // g_cp == g_ct ⇒ g = f g_ct + (1-f) g_p.
        let ct = fg(&[2.0, 4.0]);
        let p = fg(&[6.0, 8.0]);
        let g = cv_combine(&ct, &ct, &p, 0.25);
        assert_eq!(g.trunk, vec![0.25 * 2.0 + 0.75 * 6.0, 0.25 * 4.0 + 0.75 * 8.0]);
    }

    #[test]
    fn zero_predictor_reduces_to_control_gradient() {
        let ct = fg(&[3.0, -1.0]);
        let z = fg(&[0.0, 0.0]);
        let g = cv_combine(&ct, &z, &z, 0.25);
        // f·ct + (1-f)·(0 − (0 − ct)) = ct
        assert_eq!(g.trunk, ct.trunk);
    }

    #[test]
    fn split_partitions() {
        let idx: Vec<usize> = (0..16).collect();
        let (c, p) = split_indices(&idx, 0.25);
        assert_eq!(c.len(), 4);
        assert_eq!(p.len(), 12);
        let mut all = c.clone();
        all.extend(&p);
        assert_eq!(all, idx);
    }

    #[test]
    fn split_never_empty_control() {
        let idx: Vec<usize> = (0..8).collect();
        let (c, _) = split_indices(&idx, 0.001);
        assert_eq!(c.len(), 1);
        let (c, p) = split_indices(&idx, 1.0);
        assert_eq!(c.len(), 8);
        assert!(p.is_empty());
    }
}
