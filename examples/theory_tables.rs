//! Reproduce every closed-form number in the paper's Section 5:
//! Theorem 3 break-even table, Theorem 4 regime-switch/f* table, and a
//! Monte-Carlo validation of Proposition 2's variance formula.
//!
//!   cargo run --release --example theory_tables

use lgp::bench_support::Table;
use lgp::theory::{self, CostModel};

fn main() {
    let cost = CostModel::default();

    println!("== Cost model (paper Sec. 5.3) ==");
    println!("Backward = 2, Forward = 1, CheapForward = 0.7");
    println!("gamma(f) = (0.7 + 2.3 f) / 3\n");

    println!("== Theorem 3: break-even alignment rho*(f, kappa) ==");
    let mut t = Table::new(&["f", "gamma(f)", "k=0.8", "k=0.9", "k=1.0", "k=1.1", "k=1.2"]);
    for &f in &[0.05, 0.1, 0.2, 0.25, 0.3, 0.5, 0.75, 0.9] {
        let mut row = vec![format!("{f:.2}"), format!("{:.3}", cost.gamma(f))];
        for &k in &[0.8, 0.9, 1.0, 1.1, 1.2] {
            row.push(format!("{:.3}", theory::rho_star(f, k, &cost)));
        }
        t.row(row);
    }
    t.print();
    println!(
        "paper quotes: rho*(0.1,1)={:.3} (0.876)  rho*(0.2,1)={:.3} (0.802)  rho*(0.5,1)={:.3} (0.689)\n",
        theory::rho_star(0.1, 1.0, &cost),
        theory::rho_star(0.2, 1.0, &cost),
        theory::rho_star(0.5, 1.0, &cost)
    );

    println!("== Theorem 4: regime switch and optimal control fraction ==");
    let mut t = Table::new(&["kappa", "rho_switch", "f*(.65)", "f*(.7)", "f*(.8)", "f*(.9)", "f*(.95)"]);
    for &k in &[0.8, 0.9, 1.0, 1.1, 1.2] {
        let mut row = vec![format!("{k:.1}"), format!("{:.4}", theory::rho_switch(k, &cost))];
        for &r in &[0.65, 0.7, 0.8, 0.9, 0.95] {
            row.push(format!("{:.3}", theory::f_star(r, k, &cost)));
        }
        t.row(row);
    }
    t.print();
    println!(
        "paper quotes: rho_switch(1)={:.4} (0.6167)   f*(0.8,1)={:.3} (0.45)\n",
        theory::rho_switch(1.0, &cost),
        theory::f_star(0.8, 1.0, &cost)
    );

    println!("== Proposition 2: Monte-Carlo check of the variance inflation phi ==");
    let mut t = Table::new(&["f", "rho", "kappa", "phi closed-form", "phi Monte-Carlo", "rel err"]);
    for &(f, rho, kappa) in &[
        (0.25, 0.9, 1.0),
        (0.25, 0.775, 1.0), // the Thm-3 break-even point for f = 1/4
        (0.125, 0.9, 1.0),
        (0.5, 0.7, 1.2),
        (0.25, 0.5, 0.8),
    ] {
        let mc = theory::monte_carlo_phi(32, 16, f, rho, kappa, 3000, 42);
        let rel = (mc.phi_empirical - mc.phi_closed_form).abs() / mc.phi_closed_form;
        t.row(vec![
            format!("{f:.3}"),
            format!("{:.3}", mc.rho_realized),
            format!("{:.3}", mc.kappa_realized),
            format!("{:.4}", mc.phi_closed_form),
            format!("{:.4}", mc.phi_empirical),
            format!("{:.1}%", rel * 100.0),
        ]);
    }
    t.print();
}
