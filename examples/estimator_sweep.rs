//! estimator_sweep — head-to-head comparison of the full estimator zoo
//! (DESIGN.md ADR-006) on one seeded workload.
//!
//! Runs all five [`GradientEstimator`] implementations — true-backprop,
//! control-variate, predicted-lgp, multi-tangent and neural-cv — on the
//! same seeded [`Testbed`] population, through the same sharded
//! scatter/reduce executor the real session uses (ADR-004), and reports
//! the paper's variance/cost trade-off per estimator:
//!
//! - **final loss** after a fixed SGD budget,
//! - **gradient-estimate variance** (Monte Carlo over slots at the shared
//!   initial parameters — the φ(f) axis of Theorem 3),
//! - **updates/s** and mean ms/update,
//! - **backward fraction** (the cost axis: what share of examples take a
//!   true backward pass).
//!
//! The numbers land in `BENCH_estimators.json` (`lgp.bench.v1`, with the
//! ADR-006 `estimator` record dimension), validated in-process before
//! writing so a zoo member can never silently drop out of the table.
//!
//!   cargo run --release --example estimator_sweep
//!   LGP_BENCH_BUDGET=10 cargo run --release --example estimator_sweep -- \
//!       [--updates 60] [--accum 4] [--shards 2] [--f 0.25] [--seed 0]
//!
//! Runs entirely on the host — no PJRT artifacts needed.

use lgp::bench_support::json_out::{bench_doc, write_bench_doc, BenchRecord};
use lgp::bench_support::{schema, Summary, Table};
use lgp::config::EstimatorKind;
use lgp::coordinator::{exec, reduce};
use lgp::estimator::testbed::Testbed;
use lgp::estimator::{
    ControlVariate, GradientEstimator, MultiTangentForward, NeuralControlVariate, PredictedLgp,
    TrueBackprop,
};
use lgp::predictor::fit::{fit_with, FitBuffer};
use lgp::predictor::Predictor;
use lgp::tensor::Backend;
use lgp::tensor::Workspace;
use lgp::util::cli::Args;
use lgp::util::json::{num, obj, Json};
use lgp::util::rng::Pcg64;
use lgp::util::{env_parse, Stopwatch};

/// Sweep configuration: one seeded workload shared by every estimator.
struct SweepCfg {
    seed: u64,
    n: usize,
    feat: usize,
    width: usize,
    classes: usize,
    micro: usize,
    rank: usize,
    f: f64,
    tangents: usize,
    updates: usize,
    accum: usize,
    shards: usize,
    refit_every: usize,
    trials: usize,
    lr: f32,
    /// Wall-clock budget for one estimator's training loop (seconds).
    budget_each: f64,
}

/// Measured outcome for one zoo member.
struct SweepResult {
    kind: EstimatorKind,
    final_loss: f64,
    grad_variance: f64,
    updates_done: usize,
    updates_per_s: f64,
    backward_fraction: f64,
    summary: Summary,
}

/// Construct a zoo member by kind — the same wiring as
/// `SessionBuilder::build`, minus the runtime.
fn make(kind: EstimatorKind, cfg: &SweepCfg) -> Box<dyn GradientEstimator> {
    match kind {
        EstimatorKind::TrueBackprop => Box::new(TrueBackprop),
        EstimatorKind::ControlVariate => Box::new(ControlVariate::new(cfg.f)),
        EstimatorKind::PredictedLgp => Box::new(PredictedLgp::new(cfg.f)),
        EstimatorKind::MultiTangent => {
            Box::new(MultiTangentForward::new(cfg.tangents, cfg.seed))
        }
        EstimatorKind::NeuralCv => Box::new(NeuralControlVariate::new(cfg.f).with_seed(cfg.seed)),
    }
}

fn run_one(kind: EstimatorKind, cfg: &SweepCfg) -> anyhow::Result<SweepResult> {
    let mut tb = Testbed::new(cfg.seed, cfg.n, cfg.feat, cfg.width, cfg.classes);
    let man = tb.manifest(cfg.micro, cfg.rank);
    let mut est = make(kind, cfg);
    est.bind(&man)?;

    let be = Backend::blocked();
    let mut ws = Workspace::new();
    let mut pred = Predictor::new(tb.trunk_params(), tb.width, cfg.rank);
    let mut buf = FitBuffer::new(man.n_fit);
    let mut linear_fits = 0usize;

    // Index streams, seeded independently of the estimator so every zoo
    // member sees the identical example sequence.
    let mut fit_rng = Pcg64::new(cfg.seed, 0x5346); // fit-set draws
    let stream_len = (cfg.trials + cfg.updates * cfg.accum + cfg.accum) * cfg.micro;
    let mut data_rng = Pcg64::new(cfg.seed, 0x5357); // slot draws
    let stream: Vec<usize> =
        (0..stream_len).map(|_| data_rng.below(tb.n as u64) as usize).collect();

    let mut refit = |est: &mut Box<dyn GradientEstimator>,
                     pred: &mut Predictor,
                     tb: &Testbed,
                     buf: &mut FitBuffer,
                     fit_rng: &mut Pcg64,
                     ws: &mut Workspace,
                     linear_fits: &mut usize|
     -> anyhow::Result<()> {
        let idxs: Vec<usize> =
            (0..man.n_fit).map(|_| fit_rng.below(tb.n as u64) as usize).collect();
        tb.fill_fit_buffer(buf, &idxs);
        if est.owns_predictor_fit() {
            est.fit_own(be, buf, 1e-4, ws)?;
        } else {
            fit_with(be, pred, buf, 1e-4)?;
            *linear_fits += 1;
        }
        Ok(())
    };

    if est.uses_predictor() {
        refit(&mut est, &mut pred, &tb, &mut buf, &mut fit_rng, &mut ws, &mut linear_fits)?;
    }

    // Gradient-estimate variance at the shared initial parameters: the
    // per-slot estimates are i.i.d. across disjoint stream windows, so
    // the summed per-coordinate sample variance is the Monte Carlo
    // estimate of tr Cov(ĝ) — the quantity φ(f) inflates (Thm 3).
    let plan0 = est.plan(&man, est.predictor_ready(linear_fits));
    let mut trial_grads: Vec<Vec<f32>> = Vec::with_capacity(cfg.trials);
    for t in 0..cfg.trials {
        let pos = t * plan0.consumed_per_slot();
        let (g, _) = tb.slot_estimate(&*est, &plan0, &pred, &stream, pos)?;
        trial_grads.push(g.concat());
    }
    let grad_variance = {
        let t = trial_grads.len();
        let p = trial_grads[0].len();
        let mut mean = vec![0.0f64; p];
        for g in &trial_grads {
            for (m, v) in mean.iter_mut().zip(g) {
                *m += *v as f64;
            }
        }
        for m in mean.iter_mut() {
            *m /= t as f64;
        }
        let mut ss = 0.0f64;
        for g in &trial_grads {
            for (m, v) in mean.iter().zip(g) {
                let d = *v as f64 - m;
                ss += d * d;
            }
        }
        ss / (t as f64 - 1.0).max(1.0)
    };

    // Training loop: the session's scatter → fixed-order tree reduction →
    // optimizer step, against the host testbed.
    let mut workers: Vec<()> = vec![(); cfg.shards.max(1)];
    let mut cursor = cfg.trials * plan0.consumed_per_slot();
    let mut samples: Vec<f64> = Vec::with_capacity(cfg.updates);
    let sw = Stopwatch::start();
    let mut updates_done = 0usize;
    for u in 0..cfg.updates {
        if u > 0 && sw.seconds() > cfg.budget_each {
            break;
        }
        if est.uses_predictor() && cfg.refit_every > 0 && u > 0 && u % cfg.refit_every == 0 {
            refit(&mut est, &mut pred, &tb, &mut buf, &mut fit_rng, &mut ws, &mut linear_fits)?;
        }
        let plan = est.plan(&man, est.predictor_ready(linear_fits));
        let consumed = plan.consumed_per_slot();
        let base = cursor;
        let upd = Stopwatch::start();
        let outs = {
            let (tbr, predr, streamr) = (&tb, &pred, &stream);
            let est_ref: &dyn GradientEstimator = &*est;
            exec::scatter(&mut workers, cfg.accum, |_w, slot| {
                tbr.slot_estimate(est_ref, &plan, predr, streamr, base + slot * consumed)
            })?
        };
        let mut g = reduce::tree_reduce_grads(outs.into_iter().map(|(g, _)| g).collect())
            .expect("accum >= 1 slots");
        g.scale(1.0 / cfg.accum as f32);
        tb.sgd_step(&g, cfg.lr);
        samples.push(upd.seconds());
        cursor += cfg.accum * consumed;
        updates_done += 1;
    }
    let elapsed = sw.seconds();

    Ok(SweepResult {
        kind,
        final_loss: tb.population_loss() as f64,
        grad_variance,
        updates_done,
        updates_per_s: if elapsed > 0.0 { updates_done as f64 / elapsed } else { 0.0 },
        backward_fraction: est.backward_fraction(),
        summary: Summary::from_samples(samples),
    })
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(|e| anyhow::anyhow!(e))?;
    let budget: f64 = env_parse::<f64>("LGP_BENCH_BUDGET")?.unwrap_or(60.0);
    let shards = match args.parsed::<usize>("shards")? {
        Some(v) => v,
        None => env_parse::<usize>("LGP_SHARDS")?.unwrap_or(1),
    };
    let cfg = SweepCfg {
        seed: args.u64_or("seed", 0),
        n: args.usize_or("n", 256),
        feat: args.usize_or("feat", 16),
        width: args.usize_or("width", 8),
        classes: args.usize_or("classes", 5),
        micro: args.usize_or("micro", 8),
        rank: args.usize_or("rank", 2),
        f: args.f64_or("f", 0.25),
        tangents: args.usize_or("tangents", 8),
        updates: args.usize_or("updates", 60),
        accum: args.usize_or("accum", 4),
        shards: shards.max(1),
        refit_every: args.usize_or("refit-every", 10),
        trials: args.usize_or("trials", 24),
        lr: args.f64_or("lr", 0.05) as f32,
        budget_each: budget / EstimatorKind::ALL.len() as f64,
    };
    println!(
        "estimator sweep: {} updates x {} slots, shards={}, f={}, seed={} (budget {budget:.0}s)\n",
        cfg.updates, cfg.accum, cfg.shards, cfg.f, cfg.seed
    );

    let mut results: Vec<SweepResult> = Vec::new();
    for &kind in EstimatorKind::ALL {
        results.push(run_one(kind, &cfg)?);
    }

    let mut table = Table::new(&[
        "estimator", "final loss", "grad var", "updates/s", "bwd frac", "ms/update",
    ]);
    let mut records: Vec<BenchRecord> = Vec::new();
    let mut derived_rows: Vec<(&str, Json)> = Vec::new();
    for r in &results {
        table.row(vec![
            r.kind.as_str().into(),
            format!("{:.4}", r.final_loss),
            format!("{:.3e}", r.grad_variance),
            format!("{:.1}", r.updates_per_s),
            format!("{:.3}", r.backward_fraction),
            format!("{:.3}", r.summary.mean_ms()),
        ]);
        records.push(
            BenchRecord::from_summary(
                "update",
                "host",
                &[cfg.micro, cfg.feat, cfg.width],
                &r.summary,
                None,
            )
            .with_threads(cfg.shards)
            .with_estimator(r.kind.as_str()),
        );
        derived_rows.push((
            r.kind.as_str(),
            obj(vec![
                ("final_loss", num(r.final_loss)),
                ("grad_variance", num(r.grad_variance)),
                ("updates_per_s", num(r.updates_per_s)),
                ("backward_fraction", num(r.backward_fraction)),
                ("updates", num(r.updates_done as f64)),
            ]),
        ));
    }
    table.print();
    println!("\nReading the table (paper Thm 3 / EXPERIMENTS.md §Claim map):");
    println!(" - grad var is tr Cov(ĝ) at shared initial params — predicted-lgp's low");
    println!("   variance is bought with bias (see tests/estimator_unbiasedness.rs);");
    println!("   the unbiased rows trade variance against the bwd-frac cost axis.");

    let doc = bench_doc("estimators", &records, Some(obj(derived_rows)));
    // Self-validate before writing: a zoo member silently missing from
    // the table is exactly the failure the schema rule exists to catch.
    schema::validate(&doc).map_err(|e| anyhow::anyhow!("emitted document invalid: {e}"))?;
    let path = write_bench_doc("BENCH_estimators.json", &doc)?;
    println!("\nwrote {}", path.display());
    Ok(())
}
