//! Quickstart: the smallest end-to-end tour of the public API.
//!
//!   cargo run --release --example quickstart
//!
//! Loads the tiny preset's AOT artifacts, trains the ViT for 20 updates of
//! predicted gradient descent (Algorithm 1, f = 1/4 like the paper's
//! headline run), and prints the metrics a user cares about: loss,
//! validation accuracy, the measured cosine alignment ρ̂, and where the
//! run sits relative to the Theorem 3 break-even.

use lgp::config::{Algo, RunConfig};
use lgp::coordinator::Trainer;
use lgp::theory::CostModel;

fn main() -> anyhow::Result<()> {
    let mut cfg = RunConfig::default();
    cfg.artifacts_dir = std::path::PathBuf::from("artifacts/tiny");
    cfg.algo = Algo::Gpr;
    cfg.f = 0.25; // paper: prediction on 3/4 of the batch
    cfg.max_steps = 20;
    cfg.accum = 4;
    cfg.refit_every = 8;
    cfg.eval_every = 10;
    cfg.train_size = 800;
    cfg.val_size = 200;
    cfg.seed = 0;

    let mut trainer = Trainer::new(cfg)?;
    trainer.train(None)?;

    println!("\n=== quickstart summary ===");
    println!("steps:          {}", trainer.step_count());
    println!("final loss:     {:.4}", trainer.log.last().unwrap().loss);
    println!("val accuracy:   {:.3}", trainer.final_val_acc());
    println!("examples seen:  {}", trainer.examples_seen);
    println!(
        "analytic cost:  {:.0} units ({:.2} per example; vanilla would be 3.00)",
        trainer.cost_units,
        trainer.cost_units / trainer.examples_seen as f64
    );
    if let Some(a) = trainer.tracker.snapshot() {
        let cost = CostModel::default();
        println!(
            "alignment:      rho={:.3} kappa={:.3}  (Thm 3 break-even at f=0.25 needs rho >= {:.3})",
            a.rho,
            a.kappa,
            lgp::theory::rho_star(0.25, a.kappa, &cost)
        );
        println!(
            "break-even:     margin {:+.3}  ->  {}",
            a.break_even_margin(0.25, &cost),
            if a.break_even_margin(0.25, &cost) > 0.0 {
                "beating vanilla SGD at equal compute"
            } else {
                "below break-even (predictor not accurate enough yet)"
            }
        );
        println!("optimal f*:     {:.3} (Thm 4)", a.f_star(&cost));
    }
    Ok(())
}
