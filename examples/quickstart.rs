//! Quickstart: the smallest end-to-end tour of the public API.
//!
//!   cargo run --release --example quickstart
//!
//! Builds a [`TrainSession`] with the chainable `SessionBuilder` from
//! `lgp::prelude` (DESIGN.md ADR-005), trains the ViT for 20 updates of
//! predicted gradient descent (Algorithm 1, f = 1/4 like the paper's
//! headline run), and prints the metrics a user cares about: loss,
//! validation accuracy, the measured cosine alignment ρ̂, and where the
//! run sits relative to the Theorem 3 break-even.

use lgp::prelude::*;

fn main() -> anyhow::Result<()> {
    if !std::path::Path::new("artifacts/tiny/manifest.json").exists() {
        println!("SKIP: artifacts/tiny not built (run `make artifacts`)");
        return Ok(());
    }

    let mut session = SessionBuilder::new()
        .preset("tiny")
        .algo(Algo::Gpr)
        .f(0.25) // paper: prediction on 3/4 of the batch
        .max_steps(20)
        .accum(4)
        .refit_every(8)
        .eval_every(10)
        .train_size(800)
        .val_size(200)
        .seed(0)
        .build()?;
    session.run()?;

    println!("\n=== quickstart summary ===");
    println!("estimator:      {}", session.estimator().name());
    println!("steps:          {}", session.step_count());
    println!("final loss:     {:.4}", session.log.last().unwrap().loss);
    println!("val accuracy:   {:.3}", session.final_val_acc());
    println!("examples seen:  {}", session.examples_seen);
    println!(
        "analytic cost:  {:.0} units ({:.2} per example; vanilla would be 3.00)",
        session.cost_units,
        session.cost_units / session.examples_seen as f64
    );
    if let Some(a) = session.tracker.snapshot() {
        let cost = CostModel::default();
        println!(
            "alignment:      rho={:.3} kappa={:.3}  (Thm 3 break-even at f=0.25 needs rho >= {:.3})",
            a.rho,
            a.kappa,
            lgp::theory::rho_star(0.25, a.kappa, &cost)
        );
        println!(
            "break-even:     margin {:+.3}  ->  {}",
            a.break_even_margin(0.25, &cost),
            if a.break_even_margin(0.25, &cost) > 0.0 {
                "beating vanilla SGD at equal compute"
            } else {
                "below break-even (predictor not accurate enough yet)"
            }
        );
        println!("optimal f*:     {:.3} (Thm 4)", a.f_star(&cost));
    }
    Ok(())
}
