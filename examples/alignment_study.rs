//! Alignment study — the paper's Section 5.3 monitoring story, live.
//!
//! Trains GPR while recording the cosine alignment ρ̂ and scale ratio κ̂ of
//! the NTK-inspired predictor over time, the implied variance inflation
//! φ̂(f), the Theorem 3 break-even margin, and the Theorem 4 optimal f*.
//! Also validates the predictor's low-rank premise: the fraction of
//! per-example gradient energy captured by the top-r subspace.
//!
//!   cargo run --release --example alignment_study -- \
//!       [--preset tiny] [--steps 60] [--f 0.25]

use lgp::bench_support::Table;
use lgp::config::{Algo, RunConfig};
use lgp::coordinator::Trainer;
use lgp::theory::CostModel;
use lgp::util::cli::Args;
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(|e| anyhow::anyhow!(e))?;
    let preset = args.str_or("preset", "tiny");
    let steps = args.usize_or("steps", 60);
    let f = args.f64_or("f", 0.25);

    let cfg = RunConfig {
        artifacts_dir: PathBuf::from(format!("artifacts/{preset}")),
        algo: Algo::Gpr,
        f,
        accum: 4,
        max_steps: steps,
        refit_every: 10,
        eval_every: 10,
        train_size: args.usize_or("train-size", 1500),
        val_size: 300,
        seed: args.u64_or("seed", 0),
        ..RunConfig::default()
    };
    let cost = CostModel::default();
    let mut tr = Trainer::new(cfg)?;
    tr.warmup()?;

    println!("tracking alignment every refit ({} steps, refit every 10)...\n", steps);
    let mut table = Table::new(&[
        "step", "loss", "val_acc", "rho", "kappa", "phi(f)", "margin", "f*", "energy_r",
    ]);

    // Manual loop so we can snapshot at each refit. We reuse the Trainer's
    // public pieces rather than its packaged train() loop.
    let mut last_energy = f64::NAN;
    for step in 0..steps {
        let dev = tr.rt.upload_params(&tr.params)?;
        let due = tr.pred.fits == 0 && step >= 1
            || tr.pred.fits > 0 && step % 10 == 0 && step > 0;
        if due {
            if let Some(report) = tr.refit_predictor(&dev)? {
                last_energy = report.energy_captured;
            }
        }
        // one update of accumulated GPR micro-batches through the public API
        tr.cfg.max_steps = tr.step_count() + 1;
        tr.cfg.eval_every = 0;
        tr.train(None)?;
        if step % 10 == 0 || step == steps - 1 {
            let dev2 = tr.rt.upload_params(&tr.params)?;
            let val = tr.evaluate(&dev2)?;
            let row = tr.log.last().unwrap();
            let a = tr.tracker.snapshot();
            table.row(vec![
                format!("{}", tr.step_count()),
                format!("{:.4}", row.loss),
                format!("{val:.3}"),
                a.map_or("-".into(), |a| format!("{:.3}", a.rho)),
                a.map_or("-".into(), |a| format!("{:.3}", a.kappa)),
                a.map_or("-".into(), |a| format!("{:.3}", a.phi(f))),
                a.map_or("-".into(), |a| format!("{:+.3}", a.break_even_margin(f, &cost))),
                a.map_or("-".into(), |a| format!("{:.3}", a.f_star(&cost))),
                if last_energy.is_nan() { "-".into() } else { format!("{last_energy:.3}") },
            ]);
        }
    }
    table.print();

    println!("\nReading the table (paper Sec. 5.3):");
    println!(" - rho is the cosine alignment between true and predicted per-example");
    println!("   gradients; Thm 3 break-even at f={f} needs rho >= {:.3} (kappa=1).",
             lgp::theory::rho_star(f, 1.0, &cost));
    println!(" - margin = 1 - phi*gamma: positive means beating vanilla SGD per unit compute.");
    println!(" - energy_r: fraction of gradient energy in the top-r NTK subspace —");
    println!("   the empirical check of the paper's low-rank premise (Sec. 4).");
    Ok(())
}
