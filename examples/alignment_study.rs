//! Alignment study — the paper's Section 5.3 monitoring story, live.
//!
//! Trains GPR while recording the cosine alignment ρ̂ and scale ratio κ̂ of
//! the NTK-inspired predictor over time, the implied variance inflation
//! φ̂(f), the Theorem 3 break-even margin, and the Theorem 4 optimal f*.
//! Also validates the predictor's low-rank premise: the fraction of
//! per-example gradient energy captured by the top-r subspace.
//!
//! This example showcases the observer seam (DESIGN.md ADR-005): a custom
//! `TrainObserver` captures each refit's `FitReport` into shared state
//! while the stock training loop runs — no hand-rolled loop around the
//! session's internals, as the pre-ADR-005 version of this file needed.
//!
//!   cargo run --release --example alignment_study -- \
//!       [--preset tiny] [--steps 60] [--f 0.25]

use lgp::bench_support::Table;
use lgp::prelude::*;
use lgp::util::cli::Args;
use std::sync::{Arc, Mutex};

/// Captures (step, energy_captured) at every predictor refit. The
/// session owns the observer; the `Arc` hands the collected trace back
/// to `main` after the run.
struct EnergyProbe(Arc<Mutex<Vec<(usize, f64)>>>);

impl TrainObserver for EnergyProbe {
    fn on_refit(&mut self, ev: &RefitEvent) -> anyhow::Result<()> {
        self.0.lock().unwrap().push((ev.step, ev.report.energy_captured));
        Ok(())
    }
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(|e| anyhow::anyhow!(e))?;
    let preset = args.str_or("preset", "tiny");
    let steps = args.usize_or("steps", 60);
    let f = args.f64_or("f", 0.25);
    if !std::path::Path::new(&format!("artifacts/{preset}/manifest.json")).exists() {
        println!("SKIP: artifacts/{preset} not built (run `make artifacts`)");
        return Ok(());
    }

    let energies = Arc::new(Mutex::new(Vec::new()));
    let mut session = SessionBuilder::new()
        .preset(&preset)
        .algo(Algo::Gpr)
        .f(f)
        .accum(4)
        .max_steps(steps)
        .refit_every(10)
        .eval_every(10)
        .train_size(args.usize_or("train-size", 1500))
        .val_size(300)
        .seed(args.u64_or("seed", 0))
        .observer(Box::new(EnergyProbe(energies.clone())))
        .build()?;

    println!("tracking alignment every refit ({steps} steps, refit every 10)...\n");
    session.run()?;

    let cost = CostModel::default();
    let energies = energies.lock().unwrap();
    // Last refit energy at or before a given step.
    let energy_at = |step: usize| -> String {
        energies
            .iter()
            .rev()
            .find(|(s, _)| *s <= step)
            .map_or("-".into(), |(_, e)| format!("{e:.3}"))
    };

    let mut table = Table::new(&[
        "step", "loss", "val_acc", "rho", "kappa", "phi(f)", "margin", "f*", "energy_r",
    ]);
    for row in session.log.iter().filter(|r| !r.val_acc.is_nan()) {
        let have_align = row.rho.is_finite();
        table.row(vec![
            format!("{}", row.step),
            format!("{:.4}", row.loss),
            format!("{:.3}", row.val_acc),
            if have_align { format!("{:.3}", row.rho) } else { "-".into() },
            if have_align { format!("{:.3}", row.kappa) } else { "-".into() },
            if have_align { format!("{:.3}", row.phi) } else { "-".into() },
            if have_align {
                format!("{:+.3}", 1.0 - lgp::theory::q_objective(f, row.rho, row.kappa, &cost))
            } else {
                "-".into()
            },
            if have_align {
                format!("{:.3}", lgp::theory::f_star(row.rho, row.kappa, &cost))
            } else {
                "-".into()
            },
            energy_at(row.step),
        ]);
    }
    table.print();

    println!("\nReading the table (paper Sec. 5.3):");
    println!(" - rho is the cosine alignment between true and predicted per-example");
    println!(
        "   gradients; Thm 3 break-even at f={f} needs rho >= {:.3} (kappa=1).",
        lgp::theory::rho_star(f, 1.0, &cost)
    );
    println!(" - margin = 1 - phi*gamma: positive means beating vanilla SGD per unit compute.");
    println!(" - energy_r: fraction of gradient energy in the top-r NTK subspace —");
    println!("   the empirical check of the paper's low-rank premise (Sec. 4).");
    Ok(())
}
