//! End-to-end driver — the repository's Figure 1 experiment.
//!
//! Trains the ViT classifier on the synthetic CIFAR-10 substitute under an
//! equal wall-clock budget with BOTH algorithms, over multiple seeds, and
//! writes the validation-accuracy-vs-time series (mean ± standard error)
//! that regenerates the shape of the paper's Figure 1. Each run streams
//! its per-step rows through a `CsvObserver` (DESIGN.md ADR-005) instead
//! of a hand-wired CSV writer.
//!
//!   cargo run --release --example e2e_vit_cifar -- \
//!       [--preset small] [--budget 120] [--seeds 3] [--f 0.25] [--out runs/fig1]
//!
//! The paper's protocol (Sec. 7.1), scaled to this testbed: GPR predicts
//! gradients for 3/4 of each batch (f = 1/4), 8 accumulation micro-batches
//! per update, Muon lr 0.02, label smoothing 0.05, pre-augmented 2x
//! dataset, wall-clock-boxed runs, 3 seeds with standard errors.

use lgp::prelude::*;
use lgp::tensor::stats::mean_stderr;
use lgp::util::cli::Args;
use lgp::util::CsvWriter;
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(|e| anyhow::anyhow!(e))?;
    let preset = args.str_or("preset", "small");
    let budget = args.f64_or("budget", 120.0);
    let seeds = args.usize_or("seeds", 3);
    let f = args.f64_or("f", 0.25);
    let out_dir = PathBuf::from(args.str_or("out", "runs/fig1"));
    if !PathBuf::from(format!("artifacts/{preset}/manifest.json")).exists() {
        println!("SKIP: artifacts/{preset} not built (run `make artifacts`)");
        return Ok(());
    }
    std::fs::create_dir_all(&out_dir)?;

    let base = SessionBuilder::new()
        .preset(&preset)
        .f(f)
        .accum(8) // paper: 8 micro-batches per update
        .budget_secs(budget)
        .max_steps(0)
        .refit_every(25)
        .eval_every(5)
        .train_size(args.usize_or("train-size", 4000))
        .val_size(args.usize_or("val-size", 500))
        .aug_multiplier(2) // paper: pre-applied 2x augmentation
        .config()
        .clone();

    // Collect per-run (time, val_acc) curves keyed by algorithm.
    let mut curves: Vec<(Algo, u64, Vec<(f64, f64)>)> = Vec::new();
    for algo in [Algo::Baseline, Algo::Gpr] {
        for seed in 0..seeds as u64 {
            eprintln!("=== {algo:?} seed {seed} (budget {budget}s) ===");
            let csv_path = out_dir.join(format!("{algo:?}_seed{seed}.csv").to_lowercase());
            let mut session = SessionBuilder::from_config(base.clone())
                .algo(algo)
                .seed(seed)
                .observer(Box::new(CsvObserver::create(&csv_path)?))
                .build()?;
            session.run()?;
            let curve: Vec<(f64, f64)> = session
                .log
                .iter()
                .filter(|r| !r.val_acc.is_nan())
                .map(|r| (r.wall_secs, r.val_acc))
                .collect();
            eprintln!(
                "    steps={} final_val={:.3} cost_units={:.0} rho={:.3}",
                session.step_count(),
                session.final_val_acc(),
                session.cost_units,
                session.tracker.snapshot().map_or(f64::NAN, |a| a.rho)
            );
            curves.push((algo, seed, curve));
        }
    }

    // Aggregate on a common time grid: mean ± stderr across seeds.
    println!("\n=== Figure 1 (reproduced shape): val accuracy vs wall-clock ===");
    println!("{:>8}  {:>22}  {:>22}", "time(s)", "baseline (mean±se)", "GPR (mean±se)");
    let grid: Vec<f64> = (1..=10).map(|i| budget * i as f64 / 10.0).collect();
    let mut fig_csv = CsvWriter::create(
        &out_dir.join("fig1_series.csv"),
        &["time_s", "baseline_mean", "baseline_se", "gpr_mean", "gpr_se"],
    )?;
    for &t in &grid {
        let sample = |algo: Algo| -> Vec<f64> {
            curves
                .iter()
                .filter(|(a, _, _)| *a == algo)
                .filter_map(|(_, _, c)| {
                    // last evaluation at or before time t
                    c.iter().rev().find(|(ts, _)| *ts <= t).map(|(_, v)| *v)
                })
                .collect()
        };
        let (bm, bs) = mean_stderr(&sample(Algo::Baseline));
        let (gm, gs) = mean_stderr(&sample(Algo::Gpr));
        println!("{t:>8.1}  {bm:>14.3} ± {bs:<5.3}  {gm:>14.3} ± {gs:<5.3}");
        fig_csv.row(&[t, bm, bs, gm, gs])?;
    }
    println!(
        "\nCSV series written to {} (per-run logs alongside).",
        out_dir.join("fig1_series.csv").display()
    );
    println!("Paper's claim to check: the GPR column should reach any given");
    println!("accuracy level earlier than the baseline column (cheaper iterations).");
    Ok(())
}
