#!/usr/bin/env bash
# Tier-1 verification + the ADR-004 parallel-path smoke + the ADR-005
# public-API drift gate + the ADR-007 simd/pool smoke + the ADR-010
# dist-group / reshard smoke.
#
#   scripts/verify.sh            # build, tests, sharded smoke, alloc gate,
#                                # examples against the public API, simd
#                                # smoke, fmt, bench-JSON validation
#
# The LGP_SHARDS=2 pass reruns the full integration suite through the
# sharded executor — which since ADR-007 dispatches through the
# persistent parked worker pool, so this smoke also covers pool reuse:
# determinism (tests/shard_determinism.rs) guarantees bit-identical
# results, so every assertion must hold unchanged.
set -euo pipefail
cd "$(dirname "$0")/../rust"

cargo build --release
cargo test -q

# ADR-004/ADR-007 smoke: the whole suite again, scattered over 2 pool
# workers.
LGP_SHARDS=2 cargo test -q

# Zero-allocation steady state (ADR-003), serial, per-worker-thread
# (ADR-004) and across the pool dispatch protocol (ADR-007).
cargo test -q --features alloc-counter --test alloc_free_hotpath

# ADR-008 crash-safety smoke: the kill-and-resume suite again through the
# sharded executor, plus the fault-injection feature pass (torn writes,
# ENOSPC retry, every kill-point in the write protocol). The plain
# `cargo test -q` above already ran the serial resume-bit-identity suite.
LGP_SHARDS=2 cargo test -q --test checkpoint_resume
cargo test -q --features fault-inject --test checkpoint_resume --test checkpoint_format

# ADR-009 hardening + control-plane smoke: the adversarial JSON corpus
# (depth bombs, surrogate abuse, truncated escapes, overflowing numbers —
# every document a structured error, never a panic) and the serve
# end-to-end smoke — bind an ephemeral port, POST a tiny session, stream
# its chunked-JSONL events, cancel mid-run, and assert the graceful
# final checkpoint landed on disk. Both binaries also run inside
# `cargo test -q` above; the explicit pass keeps the gate visible and
# re-runs them through the sharded executor.
cargo test -q --test json_adversarial
LGP_SHARDS=2 cargo test -q --test serve_control_plane

# ADR-010 dist smoke: a 2-process × 2-shard loopback group must be
# bit-identical to `--shards 4` single-process — tests/dist_determinism.rs
# spawns the real binary as the rank-1 follower and compares whole
# checkpoint artifacts (it also kills the follower mid-run and asserts the
# leader's final checkpoint resumes onto the golden trajectory). Then the
# CLI surface end-to-end: `lgp launch --procs 2` must supervise a tiny
# group to a clean exit. Auto-skips where loopback sockets cannot be
# bound (sandboxed hosts).
sockets_ok=1
if command -v python3 >/dev/null 2>&1; then
    python3 -c 'import socket; s = socket.socket(); s.bind(("127.0.0.1", 0)); s.listen(1)' \
        2>/dev/null || sockets_ok=0
fi
if [ "$sockets_ok" = 1 ]; then
    cargo test -q --test dist_determinism
    if [ -f artifacts/tiny/manifest.json ]; then
        dist_out="$(mktemp -d)"
        cargo run --release -- launch --procs 2 --artifacts artifacts/tiny \
            --algo gpr --steps 4 --accum 4 --shards 1 --seed 3 \
            --eval-every 0 --out "$dist_out"
        rm -rf "$dist_out"
    else
        echo "SKIP: lgp launch smoke — tiny artifacts not built"
    fi
else
    echo "SKIP: dist socket smoke — cannot bind loopback sockets on this host"
fi

# ADR-010 reshard smoke (pure file I/O — runs even where the socket smoke
# skips): train a few checkpointed steps, rewrite the artifact 1 -> 4 -> 1
# shards, and the round trip must reproduce the input byte for byte. The
# shard-count-independence this leans on is exactly what the reshard zoo
# suite in tests/checkpoint_resume.rs proves across every estimator.
if [ -f artifacts/tiny/manifest.json ]; then
    rs="$(mktemp -d)"
    cargo run --release -- train --artifacts artifacts/tiny --algo gpr \
        --steps 3 --accum 4 --seed 3 --eval-every 0 --out "$rs/out" \
        --checkpoint-dir "$rs/ck" --checkpoint-every 1
    src="$(ls "$rs"/ck/ckpt-*.lgpckpt | sort | tail -n 1)"
    cargo run --release -- reshard --ckpt "$src" --from 1 --to 4 --out "$rs/m"
    cargo run --release -- reshard --dir "$rs/m" --from 4 --to 1 --out "$rs/n"
    cmp "$src" "$rs/n/$(basename "$src")"
    rm -rf "$rs"
else
    echo "SKIP: reshard smoke — tiny artifacts not built"
fi

# ADR-005 public-API drift gate: every example must build AND run against
# lgp::prelude, so an example that falls behind the session/estimator/
# observer API fails tier-1 here. Examples exit 0 with a SKIP message
# when the AOT artifacts are not built, so this also passes on stub-only
# hosts (artifact-gated, like the integration tests).
cargo build --release --examples
cargo run --release --example theory_tables > /dev/null
cargo run --release --example quickstart
cargo run --release --example alignment_study -- --steps 12
cargo run --release --example e2e_vit_cifar -- --budget 5 --seeds 1

# Estimator zoo head-to-head (ADR-006): a tiny budgeted sweep must cover
# all five estimators and emit a schema-valid BENCH_estimators.json; the
# schema's `bench == "estimators"` rule rejects any dropped zoo member.
LGP_BENCH_BUDGET=10 cargo run --release --example estimator_sweep -- \
    --updates 8 --trials 8
cargo run --release --bin bench_report -- --expect estimators

# ADR-007 simd smoke: when the host has AVX2+FMA, pin the hot-path
# backend to simd and run the fast bench suite end to end (kernels +
# sharded sweep through the pool) into a scratch dir. Auto-skips on
# scalar hosts — `--cpu-features` is the single source of truth for
# what the simd backend detected.
features="$(cargo run --release --bin bench_report -- --cpu-features)"
if [ "$features" = "avx2+fma" ]; then
    LGP_BENCH_DIR="$(mktemp -d)" LGP_BENCH_FAST=1 LGP_BACKEND=simd \
        cargo bench --bench hotpath
else
    echo "SKIP: simd smoke — host cpu features are '$features' (need avx2+fma)"
fi

# Formatting gate: rustfmt differences are API-surface noise in review.
# Skipped only where the toolchain lacks the rustfmt component. On
# failure, name the offending files (`-l`) before the diff-bearing exit
# so the log's last lines say *what* to reformat, not just that the gate
# tripped.
if cargo fmt --version >/dev/null 2>&1; then
    if ! cargo fmt -- --check -l; then
        echo "FAIL: cargo fmt --check — files listed above need rustfmt" >&2
        exit 1
    fi
else
    echo "WARN: rustfmt not installed; skipping cargo fmt --check"
fi

# Validate every committed BENCH_*.json against the lgp.bench.v1 schema.
# (The perf compare gate against BENCH_kernels.baseline.json already runs
# inside `cargo test -q`; regenerate + re-gate explicitly with
#   cargo bench --bench hotpath
#   cargo run --release --bin bench_report -- \
#       --compare ../BENCH_kernels.baseline.json ../BENCH_kernels.json
# — see EXPERIMENTS.md §Compare gate for the cross-host caveat.)
cargo run --release --bin bench_report
