#!/usr/bin/env bash
# Tier-1 verification + the ADR-004 parallel-path smoke + the ADR-005
# public-API drift gate + the ADR-007 simd/pool smoke.
#
#   scripts/verify.sh            # build, tests, sharded smoke, alloc gate,
#                                # examples against the public API, simd
#                                # smoke, fmt, bench-JSON validation
#
# The LGP_SHARDS=2 pass reruns the full integration suite through the
# sharded executor — which since ADR-007 dispatches through the
# persistent parked worker pool, so this smoke also covers pool reuse:
# determinism (tests/shard_determinism.rs) guarantees bit-identical
# results, so every assertion must hold unchanged.
set -euo pipefail
cd "$(dirname "$0")/../rust"

cargo build --release
cargo test -q

# ADR-004/ADR-007 smoke: the whole suite again, scattered over 2 pool
# workers.
LGP_SHARDS=2 cargo test -q

# Zero-allocation steady state (ADR-003), serial, per-worker-thread
# (ADR-004) and across the pool dispatch protocol (ADR-007).
cargo test -q --features alloc-counter --test alloc_free_hotpath

# ADR-008 crash-safety smoke: the kill-and-resume suite again through the
# sharded executor, plus the fault-injection feature pass (torn writes,
# ENOSPC retry, every kill-point in the write protocol). The plain
# `cargo test -q` above already ran the serial resume-bit-identity suite.
LGP_SHARDS=2 cargo test -q --test checkpoint_resume
cargo test -q --features fault-inject --test checkpoint_resume --test checkpoint_format

# ADR-009 hardening + control-plane smoke: the adversarial JSON corpus
# (depth bombs, surrogate abuse, truncated escapes, overflowing numbers —
# every document a structured error, never a panic) and the serve
# end-to-end smoke — bind an ephemeral port, POST a tiny session, stream
# its chunked-JSONL events, cancel mid-run, and assert the graceful
# final checkpoint landed on disk. Both binaries also run inside
# `cargo test -q` above; the explicit pass keeps the gate visible and
# re-runs them through the sharded executor.
cargo test -q --test json_adversarial
LGP_SHARDS=2 cargo test -q --test serve_control_plane

# ADR-005 public-API drift gate: every example must build AND run against
# lgp::prelude, so an example that falls behind the session/estimator/
# observer API fails tier-1 here. Examples exit 0 with a SKIP message
# when the AOT artifacts are not built, so this also passes on stub-only
# hosts (artifact-gated, like the integration tests).
cargo build --release --examples
cargo run --release --example theory_tables > /dev/null
cargo run --release --example quickstart
cargo run --release --example alignment_study -- --steps 12
cargo run --release --example e2e_vit_cifar -- --budget 5 --seeds 1

# Estimator zoo head-to-head (ADR-006): a tiny budgeted sweep must cover
# all five estimators and emit a schema-valid BENCH_estimators.json; the
# schema's `bench == "estimators"` rule rejects any dropped zoo member.
LGP_BENCH_BUDGET=10 cargo run --release --example estimator_sweep -- \
    --updates 8 --trials 8
cargo run --release --bin bench_report -- --expect estimators

# ADR-007 simd smoke: when the host has AVX2+FMA, pin the hot-path
# backend to simd and run the fast bench suite end to end (kernels +
# sharded sweep through the pool) into a scratch dir. Auto-skips on
# scalar hosts — `--cpu-features` is the single source of truth for
# what the simd backend detected.
features="$(cargo run --release --bin bench_report -- --cpu-features)"
if [ "$features" = "avx2+fma" ]; then
    LGP_BENCH_DIR="$(mktemp -d)" LGP_BENCH_FAST=1 LGP_BACKEND=simd \
        cargo bench --bench hotpath
else
    echo "SKIP: simd smoke — host cpu features are '$features' (need avx2+fma)"
fi

# Formatting gate: rustfmt differences are API-surface noise in review.
# Skipped only where the toolchain lacks the rustfmt component. On
# failure, name the offending files (`-l`) before the diff-bearing exit
# so the log's last lines say *what* to reformat, not just that the gate
# tripped.
if cargo fmt --version >/dev/null 2>&1; then
    if ! cargo fmt -- --check -l; then
        echo "FAIL: cargo fmt --check — files listed above need rustfmt" >&2
        exit 1
    fi
else
    echo "WARN: rustfmt not installed; skipping cargo fmt --check"
fi

# Validate every committed BENCH_*.json against the lgp.bench.v1 schema.
# (The perf compare gate against BENCH_kernels.baseline.json already runs
# inside `cargo test -q`; regenerate + re-gate explicitly with
#   cargo bench --bench hotpath
#   cargo run --release --bin bench_report -- \
#       --compare ../BENCH_kernels.baseline.json ../BENCH_kernels.json
# — see EXPERIMENTS.md §Compare gate for the cross-host caveat.)
cargo run --release --bin bench_report
