#!/usr/bin/env bash
# Tier-1 verification + the ADR-004 parallel-path smoke.
#
#   scripts/verify.sh            # build, tests, sharded smoke, alloc gate,
#                                # bench-JSON validation
#
# The LGP_SHARDS=2 pass reruns the full integration suite through the
# sharded executor: determinism (tests/shard_determinism.rs) guarantees
# bit-identical results, so every assertion must hold unchanged.
set -euo pipefail
cd "$(dirname "$0")/../rust"

cargo build --release
cargo test -q

# ADR-004 smoke: the whole suite again, scattered over 2 worker shards.
LGP_SHARDS=2 cargo test -q

# Zero-allocation steady state (ADR-003), serial and per-worker-thread
# (ADR-004).
cargo test -q --features alloc-counter --test alloc_free_hotpath

# Validate every committed BENCH_*.json against the lgp.bench.v1 schema.
# (The perf compare gate against BENCH_kernels.baseline.json already runs
# inside `cargo test -q`; regenerate + re-gate explicitly with
#   cargo bench --bench hotpath
#   cargo run --release --bin bench_report -- \
#       --compare ../BENCH_kernels.baseline.json ../BENCH_kernels.json
# — see EXPERIMENTS.md §Compare gate for the cross-host caveat.)
cargo run --release --bin bench_report
